"""Quickstart: the paper's three strategies through the one engine entry
point — ``engine.run(Request(op, inputs, strategy, substrate))``.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Comm, Layout, MigratoryStrategy, Scheme, bucketize, gather_result,
    generate_alignment_pair, partition_ell, pick_grid,
)
from repro.engine import (
    BFSInputs, BFSOp, GSANAInputs, GSANAOp, Request, SpMVInputs, SpMVOp, run,
)
from repro.sparse import edges_to_csr, erdos_renyi_edges, laplacian_2d, partition_graph

P = 8  # logical nodelets (one Emu Chick node)

# --- S1: SpMV — to replicate or not (paper §5.1) ---------------------------
a = laplacian_2d(32)  # 1024 x 1024 five-point stencil
x = jnp.asarray(np.random.default_rng(0).standard_normal(1024).astype(np.float32))
inputs = SpMVInputs(partition_ell(a, P), x)

y_rep, rep_report = run(Request(SpMVOp(), inputs, MigratoryStrategy(replicate_x=True)))
y_str, str_report = run(Request(SpMVOp(), inputs, MigratoryStrategy(replicate_x=False)))
assert np.allclose(
    np.asarray(gather_result(y_rep, 1024)), np.asarray(gather_result(y_str, 1024)),
    atol=1e-4,
)
print("S1 SpMV: replicated-x migrations =", rep_report.traffic.migrations,
      "| striped-x migrations =", str_report.traffic.migrations)

# --- S2: BFS — remote writes beat migrating threads (paper §5.2) -----------
g = partition_graph(edges_to_csr(erdos_renyi_edges(12, 8), 1 << 12), P)
parents, push = run(Request(BFSOp(), BFSInputs(g, 0), MigratoryStrategy(comm=Comm.REMOTE_WRITE)))
_, mig = run(Request(BFSOp(), BFSInputs(g, 0), MigratoryStrategy(comm=Comm.MIGRATE)))
print(f"S2 BFS: reached {push.metrics['reached']}/{1 << 12} vertices; "
      f"traffic migrate={mig.traffic.total_bytes / 1e6:.2f}MB "
      f"remote_write={push.traffic.total_bytes / 1e6:.2f}MB "
      f"({mig.traffic.total_bytes / push.traffic.total_bytes:.0f}x less)")

# --- S3: GSANA — Hilbert layout + PAIR granularity (paper §5.3) -------------
vs1, vs2, pi = generate_alignment_pair(1024, seed=1)
grid = pick_grid(1024, 32)
cap = max(bucketize(vs1, grid).cap, bucketize(vs2, grid).cap)
gi = GSANAInputs(
    vs1, vs2, bucketize(vs1, grid, cap=cap), bucketize(vs2, grid, cap=cap),
    k=4, nodelets=P, ground_truth=pi,
)
(cand, score), blk = run(Request(GSANAOp(), gi, MigratoryStrategy(layout=Layout.BLK, scheme=Scheme.PAIR)))
_, hcb = run(Request(GSANAOp(), gi, MigratoryStrategy(layout=Layout.HCB, scheme=Scheme.PAIR)))
print(f"S3 GSANA: recall@4={blk.metrics['recall_at_k']:.3f}; migrations "
      f"BLK={blk.traffic.migrations} -> HCB={hcb.traffic.migrations} "
      f"({100 * (1 - hcb.traffic.migrations / blk.traffic.migrations):.0f}% fewer)")

# --- "auto": let the traffic model pick, serve repeats from the plan cache --
y_auto, auto = run(Request(SpMVOp(), inputs, "auto"))  # autotuner: replicate_x wins
_, again = run(Request(SpMVOp(), inputs, "auto"))    # same plan key -> cache hit
print(f"auto SpMV: strategy={auto.strategy} | compile={auto.compile_seconds*1e3:.0f}ms "
      f"then cache_hit={again.cache_hit} at {again.seconds*1e6:.0f}us/call")

# --- batched serving: one compile amortized over a request stream ----------
from repro.engine import EngineService

svc = EngineService(autotune=True)
for _ in range(8):
    svc.submit(Request(SpMVOp(), inputs))
responses = svc.drain()
stats = svc.stats()
print(f"EngineService: {stats.requests} requests, {stats.compiles} compile(s), "
      f"amortization {stats.amortization:.1f} req/compile")
