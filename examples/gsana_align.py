"""GSANA graph alignment end-to-end: generate a DBLP-like pair, bucketize on
the 2-D plane, run PAIR similarity with the HCB layout, report recall +
the paper's layout/scheme comparison (paper §5.3).

    PYTHONPATH=src python examples/gsana_align.py --n 2048
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.core import (
    Scheme, bucketize, compute_similarity, generate_alignment_pair,
    gsana_effective_bw, layout_blk, layout_hcb, pick_grid, plan_stats,
    recall_at_k,
)

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--nodelets", type=int, default=8)
    ap.add_argument("--k", type=int, default=4)
    args = ap.parse_args()

    vs1, vs2, pi = generate_alignment_pair(args.n, seed=0)
    grid = pick_grid(args.n, 32)
    cap = max(bucketize(vs1, grid).cap, bucketize(vs2, grid).cap)
    b1, b2 = bucketize(vs1, grid, cap=cap), bucketize(vs2, grid, cap=cap)
    print(f"|V|={args.n} grid={grid}x{grid} bucket_cap={cap}")

    t0 = time.perf_counter()
    cand, score = compute_similarity(vs1, vs2, b1, b2, k=args.k, scheme=Scheme.PAIR)
    dt = time.perf_counter() - t0
    print(f"similarity: {dt:.2f}s  recall@{args.k}={recall_at_k(cand, pi):.3f}  "
          f"model-BW={gsana_effective_bw(vs1, vs2, b1, b2, dt) / 1e6:.0f} MB/s")

    p = args.nodelets
    for lname, pl in (
        ("BLK", layout_blk(b1, b2, vs1.n, vs2.n, p)),
        ("HCB", layout_hcb(b1, b2, p)),
    ):
        for scheme in (Scheme.ALL, Scheme.PAIR):
            st = plan_stats(vs1, vs2, b1, b2, pl, scheme, p)
            print(f"{lname}-{scheme.value.upper():4s}: migrations={st.traffic.migrations:>9d} "
                  f"model-makespan={st.makespan:>10.0f} speedup={st.speedup_model:.1f}x")
