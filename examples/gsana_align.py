"""GSANA graph alignment end-to-end: generate a DBLP-like pair, bucketize on
the 2-D plane, run PAIR similarity with the HCB layout through the engine,
report recall + the paper's layout/scheme comparison (paper §5.3).

    PYTHONPATH=src python examples/gsana_align.py --n 2048
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core import (
    Layout, MigratoryStrategy, Scheme, bucketize, generate_alignment_pair,
    layout_blk, layout_hcb, pick_grid, plan_stats,
)
from repro.engine import GSANAInputs, GSANAOp, Request, run

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--nodelets", type=int, default=8)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--substrate", default="local", help="local | mesh | pallas")
    args = ap.parse_args()

    vs1, vs2, pi = generate_alignment_pair(args.n, seed=0)
    grid = pick_grid(args.n, 32)
    cap = max(bucketize(vs1, grid).cap, bucketize(vs2, grid).cap)
    inputs = GSANAInputs(
        vs1, vs2, bucketize(vs1, grid, cap=cap), bucketize(vs2, grid, cap=cap),
        k=args.k, nodelets=args.nodelets, ground_truth=pi,
    )
    print(f"|V|={args.n} grid={grid}x{grid} bucket_cap={cap}")

    (cand, score), rep = run(Request(
        GSANAOp(), inputs,
        MigratoryStrategy(layout=Layout.HCB, scheme=Scheme.PAIR),
        args.substrate,
    ))
    print(f"similarity[{rep.substrate}]: {rep.seconds:.2f}s  "
          f"recall@{args.k}={rep.metrics['recall_at_k']:.3f}  "
          f"model-BW={rep.effective_gbps * 1e3:.0f} MB/s")

    for layout in (Layout.BLK, Layout.HCB):
        placement = (
            layout_hcb(inputs.b1, inputs.b2, args.nodelets)
            if layout == Layout.HCB
            else layout_blk(inputs.b1, inputs.b2, vs1.n, vs2.n, args.nodelets)
        )
        for scheme in (Scheme.ALL, Scheme.PAIR):
            # placement model only — no need to re-execute the similarity
            ps = plan_stats(vs1, vs2, inputs.b1, inputs.b2, placement, scheme,
                            args.nodelets)
            print(f"{layout.value.upper()}-{scheme.value.upper():4s}: "
                  f"migrations={ps.traffic.migrations:>9d} "
                  f"model-makespan={ps.makespan:>10.0f} "
                  f"speedup={ps.speedup_model:.1f}x")
