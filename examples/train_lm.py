"""End-to-end LM training with fault-tolerant supervision (deliverable b).

Presets:
    tiny  (~7M params)  — fast CPU sanity run (default)
    100m  (~100M params) — the "train a ~100M model for a few hundred steps"
                           driver; several hours on this CPU container, the
                           real target is a TPU slice.

    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 200
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
    PYTHONPATH=src python examples/train_lm.py --preset tiny --fail-at 60  # fault demo
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import main as train_main

PRESETS = {
    # (d_model, layers, batch, seq, vocab)
    "tiny": (128, 2, 8, 256, None),
    "20m": (256, 6, 8, 512, 8192),
    "100m": (640, 12, 8, 512, 32000),
}

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    d, l, b, s, v = PRESETS[args.preset]
    argv = [
        "--arch", args.arch, "--reduced", "--steps", str(args.steps),
        "--batch", str(b), "--seq", str(s), "--d-model", str(d),
        "--layers", str(l), "--ckpt-dir", args.ckpt_dir,
    ]
    if v:
        argv += ["--vocab", str(v)]
    if args.fail_at is not None:
        argv += ["--fail-at", str(args.fail_at)]
    train_main(argv)
