"""Decode serving example, end to end through the unified Request API.

Builds the ``serve-moe`` config's expert FFNs, starts a worker-loop
``EngineService`` with an SLO target, and drives a continuous-batched
``DecodeServer`` whose every decode step travels as one ``Request`` —
then cross-checks the served tokens against the single-process oracle.
See DESIGN.md §1g for the walkthrough this example mirrors.

    PYTHONPATH=src python examples/serve_decode.py
    PYTHONPATH=src python examples/serve_decode.py --dispatch ep_push --slo-ms 2000

The legacy LM prefill/decode driver still lives behind the launcher:

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --gen 32
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.serve import decode_serve_demo

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--seqs", type=int, default=8)
    ap.add_argument("--dispatch", choices=("ep_pull", "ep_push", "tp"), default="ep_pull")
    ap.add_argument("--nodelets", type=int, default=4)
    ap.add_argument("--slo-ms", type=float, default=5000.0)
    ap.add_argument("--workers", type=int, default=2)
    args = ap.parse_args()
    report = decode_serve_demo(
        args.seqs, dispatch=args.dispatch, nodelets=args.nodelets,
        slo_ms=args.slo_ms, workers=args.workers,
    )
    if not report["oracle_parity"]:
        raise SystemExit("served tokens diverged from the oracle")
