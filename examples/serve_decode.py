"""Batched serving example: prefill + greedy decode on any assigned arch.

    PYTHONPATH=src python examples/serve_decode.py --arch rwkv6-3b --gen 32
"""
import sys

sys.path.insert(0, "src")

from repro.launch.serve import main

if __name__ == "__main__":
    main()
