"""Graph500-style BFS run: build, search (both strategies), validate,
report the unified RunReport (TEPS + the paper's effective-bandwidth
metric, §5.2) per root.

    PYTHONPATH=src python examples/bfs_graph500.py --scale 14 --nodelets 8
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import Comm, MigratoryStrategy, bfs_effective_bandwidth, validate_parents
from repro.engine import BFSInputs, BFSOp, Request, run
from repro.sparse import edges_to_csr, erdos_renyi_edges, partition_graph, rmat_edges

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=14)
    ap.add_argument("--edge-factor", type=int, default=16)
    ap.add_argument("--kind", choices=["er", "rmat"], default="er")
    ap.add_argument("--nodelets", type=int, default=8)
    ap.add_argument("--roots", type=int, default=4)
    ap.add_argument("--substrate", default="local", help="local | mesh")
    args = ap.parse_args()

    n = 1 << args.scale
    gen = erdos_renyi_edges if args.kind == "er" else rmat_edges
    t0 = time.perf_counter()
    edges = gen(args.scale, args.edge_factor, seed=42)
    g = edges_to_csr(edges, n)
    pg = partition_graph(g, args.nodelets)
    print(f"kernel1 (construction): {time.perf_counter() - t0:.2f}s  "
          f"n={n} nnz={g.nnz} nodelets={args.nodelets}")

    rng = np.random.default_rng(0)
    roots = rng.integers(0, n, size=args.roots)
    for root in roots:
        inputs = BFSInputs(pg, int(root))
        parents, push = run(Request(
            BFSOp(), inputs, MigratoryStrategy(comm=Comm.REMOTE_WRITE),
            args.substrate,
        ))
        _, mig = run(Request(
            BFSOp(), inputs, MigratoryStrategy(comm=Comm.MIGRATE), args.substrate,
        ))
        ok = validate_parents(pg, int(root), np.asarray(parents))
        print(
            f"root={root}: {push.metrics['mteps']:.2f} MTEPS "
            f"({bfs_effective_bandwidth(args.scale, push.seconds, args.edge_factor) / 1e6:.0f} MB/s eff), "
            f"rounds={push.metrics['rounds']}, valid={ok}, "
            f"traffic push={push.traffic.total_bytes / 1e6:.1f}MB vs "
            f"migrate={mig.traffic.total_bytes / 1e6:.1f}MB"
        )
