"""Kernel micro-benches (interpret-mode correctness-path timings on CPU; on
TPU these run natively — the numbers here track relative effects only):
SpMV grain sweep through the Pallas grid, flash-attention block sizes,
fused topk-sim vs unfused reference."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.spmv.ops import spmv as spmv_kernel
from repro.kernels.spmv.ref import spmv_ell_reference
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.topk_sim.ops import topk_sim_pairs
from repro.core import bucketize, generate_alignment_pair, neighbor_buckets, pick_grid

from .util import emit, time_fn


def spmv_kernel_grain(full: bool = False, quick: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    r, k, n = (16384, 8, 16384) if full else ((1024, 8, 1024) if quick else (4096, 8, 4096))
    cols = jnp.asarray(rng.integers(-1, n, size=(r, k)).astype(np.int32))
    vals = jnp.asarray(np.where(np.asarray(cols) >= 0, rng.standard_normal((r, k)), 0).astype(np.float32))
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    sec_ref = time_fn(lambda: spmv_ell_reference(cols, vals, x), iters=3)
    rows.append(emit("kernel_spmv", "jnp_ref", sec_ref))
    for grain in (64, 256, 1024):
        sec = time_fn(lambda: spmv_kernel(cols, vals, x, grain=grain), iters=3)
        rows.append(emit("kernel_spmv", f"pallas_grain={grain}", sec))
    return rows


def flash_blocks(full: bool = False, quick: bool = False):
    rows = []
    rng = np.random.default_rng(1)
    b, hq, hkv, s, d = 1, 4, 2, (1024 if full else (128 if quick else 256)), 64
    q = jnp.asarray(rng.standard_normal((b, hq, s, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)).astype(np.float32))
    sec = time_fn(lambda: attention_reference(q, k, v), iters=3)
    rows.append(emit("kernel_flash", "jnp_ref", sec))
    for bq, bk in ((64, 64), (128, 128)):
        sec = time_fn(lambda: flash_attention(q, k, v, block_q=bq, block_k=bk), iters=3)
        rows.append(emit("kernel_flash", f"pallas_{bq}x{bk}", sec))
    return rows


def topk_sim(full: bool = False, quick: bool = False):
    rows = []
    n = 2048 if full else (256 if quick else 512)
    vs1, vs2, _ = generate_alignment_pair(n, seed=5)
    grid = pick_grid(n, 32)
    cap = max(bucketize(vs1, grid).cap, bucketize(vs2, grid).cap)
    b1 = bucketize(vs1, grid, cap=cap)
    b2 = bucketize(vs2, grid, cap=cap)
    nb = neighbor_buckets(grid)
    g2 = grid * grid
    pb2 = jnp.asarray(np.repeat(np.arange(g2), 9))
    pb1 = jnp.asarray(nb.reshape(-1))
    for use_kernel, name in ((False, "jnp_ref"), (True, "pallas_fused")):
        sec = time_fn(
            lambda: topk_sim_pairs(vs1, vs2, b1, b2, pb2, pb1, use_kernel=use_kernel),
            iters=3,
        )
        rows.append(emit("kernel_topk_sim", name, sec, pairs=int(g2 * 9)))
    return rows


def run(full: bool = False, quick: bool = False):
    return (
        spmv_kernel_grain(full, quick) + flash_blocks(full, quick)
        + topk_sim(full, quick)
    )
