"""Kernel micro-benches (interpret-mode correctness-path timings on CPU; on
TPU these run natively — the numbers here track relative effects only):
SpMV grain sweep through the Pallas grid, flash-attention block sizes,
fused topk-sim vs unfused reference, and the engine-level pallas-vs-local
A/B (the rows ``--require-pallas-speedup`` gates)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.spmv.ops import spmv as spmv_kernel
from repro.kernels.spmv.ref import spmv_ell_reference
from repro.kernels.spmv.stripe import build_stripe_plan, spmv_ell_stripes
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.topk_sim.ops import topk_sim_pairs
from repro.core import (
    MigratoryStrategy,
    bucketize,
    generate_alignment_pair,
    neighbor_buckets,
    partition_ell,
    pick_grid,
)

from .util import emit, emit_report, time_fn


def spmv_kernel_grain(full: bool = False, quick: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    r, k, n = (16384, 8, 16384) if full else ((1024, 8, 1024) if quick else (4096, 8, 4096))
    cols = jnp.asarray(rng.integers(-1, n, size=(r, k)).astype(np.int32))
    vals = jnp.asarray(np.where(np.asarray(cols) >= 0, rng.standard_normal((r, k)), 0).astype(np.float32))
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    sec_ref = time_fn(lambda: spmv_ell_reference(cols, vals, x), iters=3)
    rows.append(emit("kernel_spmv", "jnp_ref", sec_ref))
    for grain in (64, 256, 1024):
        sec = time_fn(lambda: spmv_kernel(cols, vals, x, grain=grain), iters=3)
        rows.append(emit("kernel_spmv", f"pallas_grain={grain}", sec))
    return rows


def flash_blocks(full: bool = False, quick: bool = False):
    rows = []
    rng = np.random.default_rng(1)
    b, hq, hkv, s, d = 1, 4, 2, (1024 if full else (128 if quick else 256)), 64
    q = jnp.asarray(rng.standard_normal((b, hq, s, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)).astype(np.float32))
    sec = time_fn(lambda: attention_reference(q, k, v), iters=3)
    rows.append(emit("kernel_flash", "jnp_ref", sec))
    for bq, bk in ((64, 64), (128, 128)):
        sec = time_fn(lambda: flash_attention(q, k, v, block_q=bq, block_k=bk), iters=3)
        rows.append(emit("kernel_flash", f"pallas_{bq}x{bk}", sec))
    return rows


def topk_sim(full: bool = False, quick: bool = False):
    rows = []
    n = 2048 if full else (256 if quick else 512)
    vs1, vs2, _ = generate_alignment_pair(n, seed=5)
    grid = pick_grid(n, 32)
    cap = max(bucketize(vs1, grid).cap, bucketize(vs2, grid).cap)
    b1 = bucketize(vs1, grid, cap=cap)
    b2 = bucketize(vs2, grid, cap=cap)
    nb = neighbor_buckets(grid)
    g2 = grid * grid
    pb2 = jnp.asarray(np.repeat(np.arange(g2), 9))
    pb1 = jnp.asarray(nb.reshape(-1))
    for use_kernel, name in ((False, "jnp_ref"), (True, "pallas_fused")):
        sec = time_fn(
            lambda: topk_sim_pairs(vs1, vs2, b1, b2, pb2, pb1, use_kernel=use_kernel),
            iters=3,
        )
        rows.append(emit("kernel_topk_sim", name, sec, pairs=int(g2 * 9)))
    return rows


def pallas_engine(full: bool = False, quick: bool = False):
    """Engine-level substrate A/B on one SpMV/BFS problem each: the same
    inputs through ``local`` vs ``pallas`` (vs ``mesh`` when the device
    count covers the partition), block-size sweep included. The
    ``spmv_local`` / ``spmv_pallas_grain=*`` pair is what run.py's
    ``--require-pallas-speedup`` gate reads: best pallas grain vs the
    jitted local path. Sized so the kernel is memory-bound, not
    dispatch-bound — per-program interpreter overhead dominates tiny
    problems and would measure the harness, not the kernel."""
    from repro.engine import BFSInputs, BFSOp, SpMVInputs, SpMVOp
    from repro.engine import run as engine_run
    from repro.sparse import (
        edges_to_csr,
        erdos_renyi_edges,
        laplacian_2d,
        partition_graph,
        skewed_matrix,
    )

    rows = []
    p = 8
    n = 160 if full else 96  # n^2-row Laplacian; quick keeps 9216 rows too
    a = laplacian_2d(n)
    x = jnp.asarray(np.random.default_rng(3).standard_normal(n * n).astype(np.float32))
    inputs = SpMVInputs(partition_ell(a, p), x)
    n_rows = inputs.a.cols.shape[0] * inputs.a.cols.shape[1]
    substrates = ["local", "pallas"] + (["mesh"] if len(jax.devices()) >= p else [])
    for sub in substrates:
        if sub == "pallas":
            grains = (n_rows,) if quick else (1024, n_rows)
            for grain in grains:
                st = MigratoryStrategy(grain=grain)
                _, rep = engine_run(SpMVOp(), inputs, st, "pallas", iters=5)
                rows.append(
                    emit_report("kernel_pallas_engine", f"spmv_pallas_grain={grain}", rep)
                )
        else:
            _, rep = engine_run(SpMVOp(), inputs, MigratoryStrategy(), sub, iters=5)
            rows.append(emit_report("kernel_pallas_engine", f"spmv_{sub}", rep))
    scale = 8 if quick else 10
    g = partition_graph(edges_to_csr(erdos_renyi_edges(scale, 8, seed=4), 1 << scale), p)
    binputs = BFSInputs(g, 0)
    for sub in substrates:
        st = MigratoryStrategy(grain=(1 << scale) if sub == "pallas" else None)
        _, rep = engine_run(BFSOp(), binputs, st, sub, iters=3)
        rows.append(emit_report("kernel_pallas_engine", f"bfs_{sub}", rep))
    # stripe-vs-dense-ELL A/B on a hub-skewed matrix (paper Table 3's
    # pathology): stripes shed the padding the dense kernel executes
    ns = 1024 if quick else 4096
    sk = skewed_matrix(ns, avg_deg=4.0, max_deg=ns // 8, seed=9)
    from repro.sparse import ell_from_csr

    e = ell_from_csr(sk)
    xs = jnp.asarray(np.random.default_rng(4).standard_normal(ns).astype(np.float32))
    plan = build_stripe_plan(e.cols, block_rows=max(64, ns // 16))
    sec = time_fn(lambda: spmv_kernel(e.cols, e.vals, xs, grain=ns), iters=3)
    rows.append(emit("kernel_pallas_engine", "spmv_skewed_ell", sec,
                     padded_slots=int(e.cols.shape[0] * e.cols.shape[1])))
    sec = time_fn(
        lambda: spmv_ell_stripes(e.cols, e.vals, xs,
                                 block_rows=max(64, ns // 16), plan=plan),
        iters=3,
    )
    rows.append(emit("kernel_pallas_engine", "spmv_skewed_stripes", sec,
                     padded_slots=int(plan.padded_slots),
                     waste_ratio=round(float(plan.waste_ratio), 3)))
    return rows


def run(full: bool = False, quick: bool = False):
    return (
        spmv_kernel_grain(full, quick) + flash_blocks(full, quick)
        + topk_sim(full, quick) + pallas_engine(full, quick)
    )
