"""Benchmark runner: one suite per paper table/figure + kernel micro-benches
+ the beyond-paper MoE dispatch A/B.

    PYTHONPATH=src python -m benchmarks.run [--bench NAME] [--full] [--quick]

Every row follows the unified RunReport schema (op, strategy_*, substrate,
seconds, effective_gbps, migrations, remote_writes, op metrics) so
``bench_results.json`` trajectories are comparable across suites and PRs.
Prints ``bench,case,us_per_call,derived...`` CSV rows and writes
``experiments/bench_results.json``.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

SUITES = {}

# subprocess-heavy suites skipped in --quick smoke runs
SLOW_SUITES = ("moe_dispatch",)


def _register():
    from . import bfs_suite, gsana_suite, kernels_suite, moe_dispatch, spmv_suite

    SUITES.update({
        "spmv": spmv_suite.run,
        "bfs": bfs_suite.run,
        "gsana": gsana_suite.run,
        "kernels": kernels_suite.run,
        "moe_dispatch": moe_dispatch.run,
    })


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=None, help="suite name (default: all)")
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument(
        "--quick", action="store_true",
        help="CI smoke mode: smallest sizes, skip subprocess suites",
    )
    ap.add_argument("--out", default=None, help="output JSON path")
    args = ap.parse_args(argv)
    _register()
    if args.bench:
        if args.bench not in SUITES:
            ap.error(f"unknown suite {args.bench!r}; choose from {sorted(SUITES)}")
        names = [args.bench]
    else:
        names = [n for n in SUITES if not (args.quick and n in SLOW_SUITES)]
    print("bench,case,us_per_call,derived")
    all_rows = []
    for name in names:
        all_rows.extend(SUITES[name](full=args.full, quick=args.quick))
    out = (
        Path(args.out)
        if args.out
        else Path(__file__).resolve().parents[1] / "experiments" / "bench_results.json"
    )
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(all_rows, indent=2, default=str))
    print(f"# wrote {out} ({len(all_rows)} rows)")


if __name__ == "__main__":
    main()
