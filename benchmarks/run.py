"""Benchmark runner: one suite per paper table/figure + kernel micro-benches
+ the beyond-paper MoE dispatch A/B.

    PYTHONPATH=src python -m benchmarks.run [--bench NAME] [--full]

Prints ``bench,case,us_per_call,derived...`` CSV rows and writes
experiments/bench_results.json.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

SUITES = {}


def _register():
    from . import bfs_suite, gsana_suite, kernels_suite, moe_dispatch, spmv_suite

    SUITES.update({
        "spmv": spmv_suite.run,
        "bfs": bfs_suite.run,
        "gsana": gsana_suite.run,
        "kernels": kernels_suite.run,
        "moe_dispatch": moe_dispatch.run,
    })


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=None, help="suite name (default: all)")
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    args = ap.parse_args(argv)
    _register()
    names = [args.bench] if args.bench else list(SUITES)
    print("bench,case,us_per_call,derived")
    all_rows = []
    for name in names:
        all_rows.extend(SUITES[name](full=args.full))
    out = Path(__file__).resolve().parents[1] / "experiments" / "bench_results.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(all_rows, indent=2, default=str))
    print(f"# wrote {out} ({len(all_rows)} rows)")


if __name__ == "__main__":
    main()
