"""Benchmark runner: one suite per paper table/figure + kernel micro-benches
+ the autotune strategy sweeps + the serving suites (sync-vs-async `serve`,
8-device `mesh`) + the engine-served MoE dispatch op (`moe`, writes
`experiments/moe_bench_results.json`) + the beyond-paper HLO-level MoE
dispatch A/B (`moe_dispatch`).

    PYTHONPATH=src python -m benchmarks.run [--bench NAME] [--full] [--quick]

Every row follows the unified RunReport schema (op, strategy_*, substrate,
seconds, cache_hit, compile_seconds, effective_gbps, migrations,
remote_writes, op metrics) so ``bench_results.json`` trajectories are
comparable across suites and PRs. Engine suites share the process-wide
compiled-plan cache, so repeated problem signatures compile once; the final
``_cache`` row records the run's hit-rate (``--require-cache-hits`` turns a
zero hit-rate into a CI failure). Prints ``bench,case,us_per_call,derived``
CSV rows and writes ``experiments/bench_results.json`` (+ the autotune
ranking table to ``experiments/autotune_ranking.json``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

SUITES = {}

# subprocess-heavy suites skipped in --quick smoke runs (still runnable
# explicitly via --bench NAME / --cluster N; the mesh-8dev and
# cluster-smoke CI jobs do exactly that)
SLOW_SUITES = ("moe_dispatch", "mesh", "cluster")


def _register():
    from . import (
        autotune_suite,
        bfs_suite,
        cluster_suite,
        gsana_suite,
        kernels_suite,
        mesh_suite,
        moe_dispatch,
        moe_suite,
        serve_suite,
        spmv_suite,
    )

    SUITES.update({
        "spmv": spmv_suite.run,
        "bfs": bfs_suite.run,
        "gsana": gsana_suite.run,
        "autotune": autotune_suite.run,
        "serve": serve_suite.run,
        "kernels": kernels_suite.run,
        "moe": moe_suite.run,
        "moe_dispatch": moe_dispatch.run,
        "mesh": mesh_suite.run,
        "cluster": cluster_suite.run,
    })


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=None, help="suite name (default: all)")
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument(
        "--quick", action="store_true",
        help="CI smoke mode: smallest sizes, skip subprocess suites",
    )
    ap.add_argument(
        "--require-cache-hits", action="store_true",
        help="fail (exit 1) if the compiled-plan cache saw zero hits",
    )
    ap.add_argument(
        "--require-overlap", action="store_true",
        help="fail (exit 1) if the serve suite's async pipeline showed zero "
        "compile/execute overlap",
    )
    ap.add_argument(
        "--workers", type=int, default=None,
        help="run the serve suite's pooled execution-plane phase with this "
        "many executor workers (8 forced host devices, mesh substrate; "
        "writes experiments/pool_stats.json)",
    )
    ap.add_argument(
        "--require-pool-speedup", type=float, default=0.0,
        help="with --workers: fail unless pooled drain throughput is at "
        "least this multiple of the workers=1 baseline (asserted inside "
        "the bench subprocess; CI uses 1.3)",
    )
    ap.add_argument(
        "--require-p99", type=float, default=0.0,
        help="fail unless every decode-serving mode's end-to-end p99 stays "
        "under this many milliseconds (asserted inside the serve suite's "
        "decode subprocess — the fail-closed SLO gate; the gate value also "
        "becomes the service's declared slo_target_seconds)",
    )
    ap.add_argument(
        "--require-pallas-speedup", type=float, default=0.0,
        help="fail unless the kernels suite's best pallas SpMV row is at "
        "least this multiple faster than the jitted local path (CI uses "
        "1.0: the fast path must not be a slow path)",
    )
    ap.add_argument(
        "--cluster", type=int, default=None, metavar="N",
        help="run the cluster suite on an N-worker localhost cluster "
        "(multi-process serving plane; fail-closed parity + distribution "
        "gates asserted inside the suite; writes "
        "experiments/cluster_stats.json)",
    )
    ap.add_argument(
        "--require-wire-reduction", type=float, default=0.0, metavar="X",
        help="with the cluster suite: fail unless the data-plane phase "
        "moved at least X times fewer bytes than the v1 inline encoding "
        "would have, with blob_hits > 0 (asserted inside the suite and "
        "recorded in experiments/cluster_stats.json; CI uses 3)",
    )
    ap.add_argument(
        "--machine-file", default=None,
        help="run suites against this pinned machine file "
        "(sets REPRO_MACHINE_PATH for this process)",
    )
    ap.add_argument(
        "--calibrate", action="store_true",
        help="run the quick microbench suite first and write a fresh "
        "machine file (to --machine-file if given, else "
        "experiments/machine.json); suites then rank in predicted seconds",
    )
    ap.add_argument(
        "--require-model-band", type=float, default=0.0,
        help="fail unless every (op, substrate)'s median modeled-vs-measured "
        "ratio lies within this factor (e.g. 5 -> [1/5, 5]); needs "
        "--calibrate or --machine-file so there is a model to gate",
    )
    ap.add_argument("--out", default=None, help="output JSON path")
    args = ap.parse_args(argv)
    # the pool gate must fail closed: a gate with no pool phase to run
    # (missing/1-wide --workers, or a suite selection that skips serve)
    # would otherwise exit green without ever measuring anything
    if args.require_pool_speedup > 0 and (args.workers is None or args.workers < 2):
        ap.error("--require-pool-speedup needs --workers >= 2 to have a pool to gate")
    if args.workers is not None and args.bench not in (None, "serve"):
        ap.error("--workers drives the serve suite's pool phase; use --bench serve")
    if args.cluster is not None:
        if args.bench not in (None, "cluster"):
            ap.error("--cluster runs the cluster suite; drop --bench or use "
                     "--bench cluster")
        if args.cluster < 1:
            ap.error("--cluster needs at least 1 worker (CI uses 2)")
    # the wire gate fails closed: without the cluster suite in the run
    # there is no data-plane phase to measure, and an unmeasured gate must
    # not pass green
    if args.require_wire_reduction > 0 and args.cluster is None and (
        args.bench != "cluster"
    ):
        ap.error("--require-wire-reduction gates the cluster suite's "
                 "data-plane phase; use --cluster N (or --bench cluster)")
    # the SLO gate fails closed too: gating p99 without the serve suite's
    # decode phase in the run would exit green having measured nothing
    if args.require_p99 > 0 and args.bench not in (None, "serve"):
        ap.error("--require-p99 gates the serve suite's decode phase; "
                 "use --bench serve (or no --bench)")
    # the model gate fails closed the same way: without a calibration there
    # are no predicted columns, and an empty gate must not pass green
    if args.require_model_band > 0 and not (args.calibrate or args.machine_file):
        ap.error("--require-model-band needs --calibrate or --machine-file "
                 "to have a model to gate")
    if args.machine_file:
        os.environ["REPRO_MACHINE_PATH"] = str(Path(args.machine_file).resolve())
    if args.calibrate:
        from repro.machine import reset_default_machine_cache
        from repro.machine.machine import default_machine_path
        from repro.machine.microbench import calibrate

        path = calibrate(quick=True).save(default_machine_path())
        reset_default_machine_cache()
        print(f"# calibrated machine file -> {path}")
    _register()
    if args.bench:
        if args.bench not in SUITES:
            ap.error(f"unknown suite {args.bench!r}; choose from {sorted(SUITES)}")
        names = [args.bench]
    elif args.cluster is not None:
        names = ["cluster"]  # --cluster N == --bench cluster with N workers
    else:
        names = [n for n in SUITES if not (args.quick and n in SLOW_SUITES)]
    print("bench,case,us_per_call,derived")
    from .util import machine_header

    header = machine_header()
    print(
        f"# machine file: {header['machine_file']} "
        f"(calibrated={header['machine_calibrated']})"
    )
    all_rows = [{"bench": "_machine", "case": "header", **header}]
    for name in names:
        if name == "serve":
            all_rows.extend(SUITES[name](
                full=args.full, quick=args.quick, workers=args.workers,
                min_pool_speedup=args.require_pool_speedup,
                require_p99_ms=args.require_p99,
            ))
        elif name == "cluster":
            all_rows.extend(SUITES[name](
                full=args.full, quick=args.quick,
                n_workers=args.cluster if args.cluster is not None else 2,
                require_wire_reduction=args.require_wire_reduction or None,
            ))
        else:
            all_rows.extend(SUITES[name](full=args.full, quick=args.quick))

    from repro.engine import default_cache

    cache_stats = default_cache().stats()
    all_rows.append({"bench": "_cache", "case": "default_cache", **cache_stats})
    print(
        f"# plan cache: {cache_stats['entries']} entries, "
        f"{cache_stats['hits']} hits / {cache_stats['misses']} misses "
        f"(hit rate {cache_stats['hit_rate']:.0%}), "
        f"{cache_stats['compile_seconds_total']:.2f}s compiling"
    )
    out = (
        Path(args.out)
        if args.out
        else Path(__file__).resolve().parents[1] / "experiments" / "bench_results.json"
    )
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(all_rows, indent=2, default=str))
    print(f"# wrote {out} ({len(all_rows)} rows)")
    if args.require_cache_hits and cache_stats["hits"] == 0:
        print("# FAIL: compiled-plan cache saw zero hits", file=sys.stderr)
        sys.exit(1)
    if args.require_overlap:
        async_rows = [
            r for r in all_rows
            if r.get("bench") == "serve" and r.get("case") == "async_worker"
        ]
        if not async_rows or all(r.get("overlap_ratio", 0) <= 0 for r in async_rows):
            print(
                "# FAIL: serve suite showed zero compile/execute overlap",
                file=sys.stderr,
            )
            sys.exit(1)
    if args.require_pallas_speedup > 0:
        _gate_pallas_speedup(all_rows, args.require_pallas_speedup)
    if args.require_model_band > 0:
        _gate_model_band(all_rows, args.require_model_band)


def _gate_pallas_speedup(all_rows: list, min_speedup: float) -> None:
    """The kernels suite's engine A/B must show the pallas fast path is
    one: best ``spmv_pallas_grain=*`` seconds vs the ``spmv_local`` row.
    Fails closed — a gate with no rows to read (suite skipped or renamed)
    must not pass green."""
    local = [
        r for r in all_rows
        if r.get("bench") == "kernel_pallas_engine" and r.get("case") == "spmv_local"
    ]
    pallas = [
        r for r in all_rows
        if r.get("bench") == "kernel_pallas_engine"
        and str(r.get("case", "")).startswith("spmv_pallas_grain=")
    ]
    if not local or not pallas:
        print(
            "# FAIL: --require-pallas-speedup found no kernel_pallas_engine "
            "spmv rows (did the kernels suite run?)",
            file=sys.stderr,
        )
        sys.exit(1)
    best = min(pallas, key=lambda r: float(r["seconds"]))
    speedup = float(local[0]["seconds"]) / float(best["seconds"])
    print(
        f"# pallas speedup: local {float(local[0]['seconds'])*1e6:.1f}us / "
        f"best pallas ({best['case']}) {float(best['seconds'])*1e6:.1f}us "
        f"= {speedup:.2f}x (need >= {min_speedup:g})"
    )
    if speedup < min_speedup:
        print(
            f"# FAIL: pallas SpMV fast path is {speedup:.2f}x the jitted "
            f"local path, below the {min_speedup:g}x floor",
            file=sys.stderr,
        )
        sys.exit(1)


def _gate_model_band(all_rows: list, band: float) -> None:
    """Per-(op, substrate) median modeled-vs-measured ratio must lie within
    [1/band, band]. model_error columns only exist on rows measured under a
    calibrated machine file (subprocess suites with a different forced
    topology legitimately carry none), but *zero* gated rows means the
    calibration never reached the suites — fail, don't pass vacuously."""
    import statistics

    groups: dict[tuple, list] = {}
    for r in all_rows:
        if r.get("model_error") is not None and r.get("op") and r.get("substrate"):
            groups.setdefault((r["op"], r["substrate"]), []).append(
                float(r["model_error"])
            )
    if not groups:
        print(
            "# FAIL: --require-model-band found no rows with model_error "
            "(did calibration happen in this process?)",
            file=sys.stderr,
        )
        sys.exit(1)
    failed = False
    for (op, sub), errs in sorted(groups.items()):
        med = statistics.median(errs)
        ok = (1.0 / band) <= med <= band
        print(
            f"# model band {op}/{sub}: median predicted/measured = {med:.3f} "
            f"over {len(errs)} rows ({'ok' if ok else 'OUT OF BAND'})"
        )
        if not ok:
            failed = True
    if failed:
        print(
            f"# FAIL: modeled-vs-measured outside the {band}x band "
            "(unit-level model bug?)",
            file=sys.stderr,
        )
        sys.exit(1)


if __name__ == "__main__":
    main()
