"""Beyond-paper benchmark (paper §7 put/get asymmetry at LM scale): MoE
dispatch strategy A/B — remote-write push (all_to_all) vs migrate pull
(all_gather) vs tp (local dispatch) — measured as per-device collective wire
bytes from the lowered HLO on an 8-device sub-mesh (subprocess, so the main
process keeps 1 device). Dispatch modes are derived from MigratoryStrategy
via ``repro.models.moe.dispatch_from_strategy`` (the engine mapping)."""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .util import emit

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core.strategies import Comm, MigratoryStrategy
from repro.models.config import ModelConfig
from repro.models.layers import Ctx
from repro.models.moe import dispatch_from_strategy, moe_params, moe_sublayer
from repro.models.sharding import make_rules
from repro.launch import roofline

cfg = ModelConfig(
    name="bench-moe", family="moe", num_layers=1, d_model=512, num_heads=8,
    num_kv_heads=8, d_ff=1024, vocab_size=1024, num_experts=16,
    experts_per_token=2, moe_d_ff=1024, dtype="float32", remat=False,
)
mesh = make_mesh((4, 2), ("data", "model"))
rules = make_rules(mesh, num_experts=cfg.num_experts, num_heads=8, num_kv_heads=8)
ctx = Ctx(cfg=cfg, mesh=mesh, rules=rules)
params = moe_params(cfg, jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (8, 128, 512))
cases = {
    "ep_push": MigratoryStrategy(comm=Comm.REMOTE_WRITE),
    "ep_pull": MigratoryStrategy(comm=Comm.MIGRATE),
    "tp": None,  # S1 replication fallback (explicit mode)
}
out = {}
for name, strat in cases.items():
    mode = name if strat is None else dispatch_from_strategy(
        strat, num_experts=cfg.num_experts, data_axis=mesh.shape["data"])
    assert strat is None or mode == name, (name, mode)
    with mesh:
        co = jax.jit(lambda p, x: moe_sublayer(ctx, p, x, dispatch=mode)).lower(params, x).compile()
    rep = roofline.analyze(co.as_text())
    out[name] = {
        "collective_wire_bytes": rep.bytes_collective,
        "by_kind": rep.collective_counts,
        "flops": rep.flops,
        "strategy_comm": strat.comm.value if strat else "replicate",
    }
print("RESULT" + json.dumps(out))
"""


def run(full: bool = False, quick: bool = False):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True,
        timeout=600,
    )
    rows = []
    for line in r.stdout.splitlines():
        if line.startswith("RESULT"):
            data = json.loads(line[len("RESULT"):])
            for mode, d in data.items():
                rows.append(emit(
                    "moe_dispatch", mode, 0.0,
                    op="moe_dispatch", substrate=mode,
                    strategy_comm=d["strategy_comm"],
                    collective_bytes=d["collective_wire_bytes"],
                    collective_wire_mb=round(d["collective_wire_bytes"] / 1e6, 3),
                    kinds="|".join(f"{k}:{round(v/1e6,2)}MB" for k, v in d["by_kind"].items()),
                ))
    if not rows:
        print("moe_dispatch,FAILED,0.0,", r.stderr[-500:])
    return rows
