"""Serving benchmark: the synchronous drain vs the async worker-loop
pipeline on an identical mixed SpMV/BFS request stream.

Each phase runs **cold in its own subprocess** so both pay their own
tracing + XLA compiles and neither inherits the other's (or the parent
bench run's) process-level jax cache — the A/B isolates scheduling: the
sync drain serializes each plan-key group's compile against its members'
execution; the async pipeline hides the compile of one group under the
execution of another. The ``async_worker`` row reports the sustained
request rate plus ``overlap_ratio`` — the fraction of compile-stage time
hidden under execution. ISSUE 3 acceptance requires ``overlap_ratio > 0``
in the ``--quick`` CI smoke (``benchmarks/run.py --require-overlap`` gates
it). At quick sizes execution is tiny next to compile, so the wall-clock
win is modest; the overlap ratio is the signal that the pipeline works.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

from .util import emit

SCRIPT = r"""
import json, sys
import jax.numpy as jnp
import numpy as np
from repro.core import Comm, MigratoryStrategy, partition_ell
from repro.engine import BFSInputs, EngineService, PlanCache, SpMVInputs
from repro.sparse import edges_to_csr, erdos_renyi_edges, laplacian_2d, partition_graph

phase, out_path = sys.argv[1], sys.argv[2]
grids = [int(g) for g in sys.argv[3].split(",")]
scale, per = int(sys.argv[4]), int(sys.argv[5])

rng = np.random.default_rng(0)
cases = []
for g in grids:
    a = laplacian_2d(g)
    x = jnp.asarray(rng.standard_normal(g * g).astype(np.float32))
    inputs = SpMVInputs(partition_ell(a, 8), x)
    for st in (MigratoryStrategy(), MigratoryStrategy(replicate_x=False)):
        cases.append(("spmv", inputs, st))
g = edges_to_csr(erdos_renyi_edges(scale, 6, seed=1), 1 << scale)
cases.append(("bfs", BFSInputs(partition_graph(g, 8), 0),
              MigratoryStrategy(comm=Comm.REMOTE_WRITE)))
requests = [case for case in cases for _ in range(per)]

if phase == "sync":
    svc = EngineService(cache=PlanCache())
    for op, inputs, st in requests:
        svc.submit(op, inputs, st)
    responses = svc.drain()
else:
    svc = EngineService(cache=PlanCache(), max_queue_depth=4096,
                        qos={"bfs": 2.0}, batch_window=0.02)
    svc.start()
    futures = [svc.submit(op, inputs, st) for op, inputs, st in requests]
    responses = [f.result(timeout=600) for f in futures]
    svc.stop()

assert len(responses) == len(requests)
with open(out_path, "w") as f:
    json.dump(svc.stats().to_dict(), f)
print(f"SERVE-{phase.upper()}-OK")
"""


def _run_phase(phase: str, grids, scale: int, per: int) -> dict:
    env = dict(os.environ)
    src = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as tmp:
        out_path = tmp.name
    try:
        proc = subprocess.run(
            [sys.executable, "-c", SCRIPT, phase, out_path,
             ",".join(str(g) for g in grids), str(scale), str(per)],
            env=env, capture_output=True, text=True, timeout=1800,
        )
        if proc.returncode != 0 or f"SERVE-{phase.upper()}-OK" not in proc.stdout:
            raise RuntimeError(
                f"serve {phase} subprocess failed (rc={proc.returncode}):\n"
                f"stdout={proc.stdout}\nstderr={proc.stderr}"
            )
        return json.loads(Path(out_path).read_text())
    finally:
        Path(out_path).unlink(missing_ok=True)


def run(full: bool = False, quick: bool = False):
    if quick:
        grids, scale, per = (12, 16), 8, 8
    elif full:
        grids, scale, per = (32, 48, 64), 11, 32
    else:
        grids, scale, per = (16, 24), 9, 12
    rows = []
    sync = _run_phase("sync", grids, scale, per)
    rows.append(emit(
        "serve", "sync_drain", sync["wall_seconds"],
        requests=sync["requests"],
        req_per_s=round(sync["requests_per_second"], 1),
        compiles=sync["compiles"],
        cache_hits=sync["cache_hits"],
        queue_wait_p95=round(sync["queue_wait_p95"], 6),
        service_p95=round(sync["service_p95"], 6),
    ))
    a = _run_phase("async", grids, scale, per)
    rows.append(emit(
        "serve", "async_worker", a["wall_seconds"],
        requests=a["requests"],
        req_per_s=round(a["requests_per_second"], 1),
        compiles=a["compiles"],
        cache_hits=a["cache_hits"],
        overlap_seconds=a["overlap_seconds"],  # unrounded: run.py gates on > 0
        overlap_ratio=a["overlap_ratio"],
        busy_seconds=round(a["busy_seconds"], 4),
        queue_depth_hwm=a["queue_depth_hwm"],
        rejected=a["rejected"],
        dedup_hits=a["dedup_hits"],
        queue_wait_p50=round(a["queue_wait_p50"], 6),
        queue_wait_p95=round(a["queue_wait_p95"], 6),
        queue_wait_p99=round(a["queue_wait_p99"], 6),
        service_p50=round(a["service_p50"], 6),
        service_p95=round(a["service_p95"], 6),
        service_p99=round(a["service_p99"], 6),
    ))
    speedup = (
        sync["wall_seconds"] / a["wall_seconds"] if a["wall_seconds"] > 0 else 0.0
    )
    rows.append(emit(
        "serve", "async_vs_sync", a["wall_seconds"],
        sync_wall_seconds=round(sync["wall_seconds"], 4),
        speedup=round(speedup, 3),
        overlap_ratio=round(a["overlap_ratio"], 4),
    ))
    if a["overlap_ratio"] <= 0:
        print("# WARN: serve async_worker saw zero compile/execute overlap")
    return rows
