"""Serving benchmark: the synchronous drain vs the async worker-loop
pipeline on an identical mixed SpMV/BFS request stream, plus (with
``workers=N``) the pooled execution-plane A/B.

Each phase runs **cold in its own subprocess** so both pay their own
tracing + XLA compiles and neither inherits the other's (or the parent
bench run's) process-level jax cache — the A/B isolates scheduling: the
sync drain serializes each plan-key group's compile against its members'
execution; the async pipeline hides the compile of one group under the
execution of another. The ``async_worker`` row reports the sustained
request rate plus ``overlap_ratio`` — the fraction of compile-stage time
hidden under execution. ISSUE 3 acceptance requires ``overlap_ratio > 0``
in the ``--quick`` CI smoke (``benchmarks/run.py --require-overlap`` gates
it). At quick sizes execution is tiny next to compile, so the wall-clock
win is modest; the overlap ratio is the signal that the pipeline works.

The **pool phase** (ISSUE 5 acceptance; ``--workers N`` on the runner) is
one subprocess with 8 forced host devices serving a ≥4-plan-key mixed-op
mesh load twice — ``EngineService(workers=1)`` then ``workers=N`` — from
identical cold caches. Plan-key groups pin to per-slot device windows
(substrate-aware placement), so pooled drain throughput reflects genuinely
parallel channels; the subprocess asserts results stay bit-identical to
sequential ``engine.run``, measures the pooled/single throughput ratio
(optionally gating it, CI uses ≥ 1.3x), runs an in-flight coalescing burst
(``dedup_hits``/``dedup_coalesced``), and writes the per-worker stats
artifact ``experiments/pool_stats.json``.

The **decode phase** (ISSUE 8 acceptance) serves continuous-batched MoE
decode — the ``serve-moe`` config's expert FFNs behind ``moe_dispatch``
transport, every step one ``Request`` through the worker-loop service with
an SLO target — across all three dispatch modes, asserts the served tokens
are bit-identical to the single-process oracle under a staggered join/leave
schedule, and writes ``experiments/decode_bench_results.json``. With
``require_p99_ms > 0`` (CI: ``benchmarks/run.py --require-p99``), the
subprocess fails unless every mode's end-to-end p99 meets the target — the
fail-closed SLO gate.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

from .util import emit

POOL_STATS_PATH = (
    Path(__file__).resolve().parents[1] / "experiments" / "pool_stats.json"
)

DECODE_STATS_PATH = (
    Path(__file__).resolve().parents[1] / "experiments" / "decode_bench_results.json"
)

SCRIPT = r"""
import json, sys
import jax.numpy as jnp
import numpy as np
from repro.core import Comm, MigratoryStrategy, partition_ell
from repro.engine import BFSInputs, EngineService, PlanCache, Request, SpMVInputs
from repro.sparse import edges_to_csr, erdos_renyi_edges, laplacian_2d, partition_graph

phase, out_path = sys.argv[1], sys.argv[2]
grids = [int(g) for g in sys.argv[3].split(",")]
scale, per = int(sys.argv[4]), int(sys.argv[5])

rng = np.random.default_rng(0)
cases = []
for g in grids:
    a = laplacian_2d(g)
    x = jnp.asarray(rng.standard_normal(g * g).astype(np.float32))
    inputs = SpMVInputs(partition_ell(a, 8), x)
    for st in (MigratoryStrategy(), MigratoryStrategy(replicate_x=False)):
        cases.append(("spmv", inputs, st))
g = edges_to_csr(erdos_renyi_edges(scale, 6, seed=1), 1 << scale)
cases.append(("bfs", BFSInputs(partition_graph(g, 8), 0),
              MigratoryStrategy(comm=Comm.REMOTE_WRITE)))
requests = [case for case in cases for _ in range(per)]

if phase == "sync":
    svc = EngineService(cache=PlanCache())
    for op, inputs, st in requests:
        svc.submit(Request(op, inputs, st))
    responses = svc.drain()
else:
    svc = EngineService(cache=PlanCache(), max_queue_depth=4096,
                        qos={"bfs": 2.0}, batch_window=0.02)
    svc.start()
    futures = [svc.submit(Request(op, inputs, st)) for op, inputs, st in requests]
    responses = [f.result(timeout=600) for f in futures]
    svc.stop()

assert len(responses) == len(requests)
with open(out_path, "w") as f:
    json.dump(svc.stats().to_dict(), f)
print(f"SERVE-{phase.upper()}-OK")
"""


POOL_SCRIPT = r"""
import os
# one intra-op thread per XLA call: each executor-pool worker is one
# independent channel, so the pool — not XLA's intra-op fan-out — is the
# parallelism under measurement (both A/B sides run under the same flags)
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=8"
    + " --xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1"
).strip()
import json, sys, time
import numpy as np, jax, jax.numpy as jnp
from repro.core import Comm, MigratoryStrategy, partition_ell
from repro.engine import (
    BFSInputs, EngineService, OpSpec, PlanCache, Request, SpMVInputs, SpMVOp,
    placement_table, register_op, run,
)
from repro.engine.registry import kernel
from repro.sparse import edges_to_csr, erdos_renyi_edges, laplacian_2d, partition_graph

out_path = sys.argv[1]
grid, scale, tokens = int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4])
reps, workers = int(sys.argv[5]), int(sys.argv[6])
min_speedup = float(sys.argv[7])
assert len(jax.devices()) >= 8, f"forced-device count failed: {jax.devices()}"

from repro.engine import MoEDispatchInputs

# --- spmv_link: SpMV + modeled interconnect latency (registry one-file op) ---
# The forced-host-device mesh emulates the Chick's nodelets with a
# zero-latency interconnect, which misrepresents the regime the paper
# targets: migratory threads exist to HIDE per-migration link latency
# (paper SS2's ~us-scale round-trips; scaled up here so the A/B measures
# channel concurrency rather than host-CPU oversubscription). spmv_link is
# the real SpMV kernel followed by an ordered host callback that sleeps a
# modeled per-call link latency off-CPU — results stay bit-identical, and
# the single-executor baseline serializes exactly the latency the pool's
# independent channels hide. Registered through the kernel registry, so it
# is also a live test of the "new op without touching the engine" path.
LINK_SECONDS = 0.016

def _link_stall():
    time.sleep(LINK_SECONDS)

from jax.experimental import io_callback

def _with_link(sub, a, x, *, strategy):
    y = sub.kernel("spmv")(a, x, strategy=strategy)
    io_callback(_link_stall, None, ordered=True)
    return y

kernel("spmv_link", "mesh")(_with_link)
kernel("spmv_link", "local")(_with_link)

class SpMVLinkOp(SpMVOp):
    name = "spmv_link"

register_op(OpSpec(name="spmv_link", factory=SpMVLinkOp, inputs_type=SpMVInputs))

# >= 4 plan keys of mixed ops, partitioned P=1 so each key's executable fits
# inside one worker's device window: the channels are the parallelism.
# Heavy keys first — affinity placement assigns new keys round-robin, so
# submission order spreads the four execution-bound keys over four slots;
# the two link-latency SpMV keys ride along as the mixed-op tail.
rng = np.random.default_rng(0)
gr = edges_to_csr(erdos_renyi_edges(scale, 8, seed=1), 1 << scale)
bfs_inputs = BFSInputs(partition_graph(gr, 1), 0)
moe_inputs = MoEDispatchInputs(
    x=jnp.asarray(rng.standard_normal((tokens, 128)).astype(np.float32)),
    router=jnp.asarray(rng.standard_normal((128, 32)).astype(np.float32)),
    nodelets=1,
)
a = laplacian_2d(grid)
x = jnp.asarray(rng.standard_normal(grid * grid).astype(np.float32))
spmv_inputs = SpMVInputs(partition_ell(a, 1), x)
cases = [
    ("bfs", bfs_inputs, MigratoryStrategy(comm=Comm.REMOTE_WRITE)),
    ("bfs", bfs_inputs, MigratoryStrategy(comm=Comm.MIGRATE)),
    ("moe_dispatch", moe_inputs, MigratoryStrategy(comm=Comm.REMOTE_WRITE)),
    ("moe_dispatch", moe_inputs, MigratoryStrategy(comm=Comm.MIGRATE)),
    ("spmv_link", spmv_inputs, MigratoryStrategy()),
    ("spmv_link", spmv_inputs, MigratoryStrategy(replicate_x=False)),
]
assert len(cases) >= 4

seq_cache = PlanCache()
expected = [
    run(op, inputs, st, "local", iters=1, warmup=0, cache=seq_cache)[0]
    for op, inputs, st in cases
]

def make_service(n_workers):
    svc = EngineService(cache=PlanCache(), substrate="mesh", workers=n_workers,
                        max_queue_depth=8192)
    svc.start()
    # warm every plan key on its slot so the timed bursts are pure execution
    for case in cases:
        svc.submit(Request(*case))
    svc.flush(timeout=1800)
    return svc

def timed_burst(svc):
    t0 = time.perf_counter()
    futs = [(i % len(cases), svc.submit(Request(*cases[i % len(cases)])))
            for i in range(reps * len(cases))]
    resps = [(ci, f.result(timeout=1800)) for ci, f in futs]
    wall = time.perf_counter() - t0
    for ci, resp in resps:
        assert resp.report.substrate == "mesh"
        np.testing.assert_array_equal(
            np.asarray(resp.result), np.asarray(expected[ci]))
    return len(resps) / wall, wall

# alternate single-executor and pooled bursts in adjacent pairs and take
# the median of the per-pair ratios over a FIXED number of pairs: machine
# noise (noisy-neighbor CPU, allocator state) drifts on second scales, so
# a ratio of two bursts run back-to-back sees the same conditions on both
# sides, and the median over a predetermined sample discards the odd burst
# straddling a shift without optional-stopping bias (the sample size never
# depends on how the ratios are coming out).
svc1, svcN = make_service(1), make_service(workers)
pairs = 5
thr1s, thrNs, wall1s, wallNs = [], [], [], []

def median(xs):
    s = sorted(xs)
    return (s[len(s) // 2] + s[(len(s) - 1) // 2]) / 2

for _ in range(pairs):
    t, w = timed_burst(svc1)
    thr1s.append(t); wall1s.append(w)
    t, w = timed_burst(svcN)
    thrNs.append(t); wallNs.append(w)
ratios = [tN / t1 for t1, tN in zip(thr1s, thrNs)]
stats1 = svc1.stats().to_dict()
statsN = svcN.stats().to_dict()
assert stats1["errors"] == 0 and statsN["errors"] == 0
svc1.stop(); svcN.stop()
ratios = sorted(ratios)
speedup = median(ratios)
thr1, thrN = median(thr1s), median(thrNs)
wall1, wallN = median(wall1s), median(wallNs)

# in-flight coalescing burst: duplicates attach to the pending primary
svc = EngineService(cache=PlanCache(), substrate="mesh", workers=workers,
                    dedup=True, batch_window=0.2)
svc.start()
prim = svc.submit(Request(*cases[0]))
dups = [svc.submit(Request(*cases[0])) for _ in range(8)]
for f in [prim] + dups:
    f.result(timeout=1800)
svc.stop()
dedup_stats = svc.stats()
assert dedup_stats.dedup_hits >= 1, "coalescing burst produced no dedup hits"
assert dedup_stats.dedup_coalesced >= 1

# host parallel-capacity calibration: how much the host actually scales two
# independent CPU-bound processes. On shared/sandboxed hosts this can dip
# toward 1.0, capping ANY pool speedup — recording it makes a sub-gate
# reading interpretable (pool efficiency = speedup / capacity).
import subprocess as _sp
_spin = "x=1.0\nfor i in range(6_000_000): x = x*1.0000001 if x < 2 else 1.0"
_t0 = time.perf_counter()
_sp.run([sys.executable, "-c", _spin])
_one = time.perf_counter() - _t0
_t0 = time.perf_counter()
_ps = [_sp.Popen([sys.executable, "-c", _spin]) for _ in range(2)]
for _p in _ps:
    _p.wait()
_two = time.perf_counter() - _t0
host_capacity = 2 * _one / _two if _two > 0 else 0.0

from repro.machine import default_machine, default_machine_path
_prof = default_machine()
record = {
    "machine_file": str(default_machine_path()),
    "machine_calibrated": _prof.calibrated,
    "grid": grid, "scale": scale, "tokens": tokens, "reps": reps,
    "plan_keys": len(cases), "modeled_link_seconds": LINK_SECONDS,
    "host_parallel_capacity": host_capacity,
    "workers": workers, "requests_per_burst": reps * len(cases),
    "throughput_1": thr1, "throughput_pooled": thrN,
    "throughput_1_bursts": thr1s, "throughput_pooled_bursts": thrNs,
    "pairwise_ratios": ratios,
    "burst_wall_1": wall1, "burst_wall_pooled": wallN,
    "pool_speedup": speedup, "bit_identical": True,
    "dedup_hits": dedup_stats.dedup_hits,
    "dedup_coalesced": dedup_stats.dedup_coalesced,
    "placement": placement_table(),
    "stats_workers_1": stats1, "stats_workers_pooled": statsN,
}
with open(out_path, "w") as f:
    json.dump(record, f, indent=2, default=str)
if min_speedup > 0:
    assert speedup >= min_speedup, (
        f"pooled throughput {thrN:.1f} req/s is only {speedup:.2f}x the "
        f"single-executor {thr1:.1f} req/s (gate: {min_speedup}x)")
print("SERVE-POOL-OK", json.dumps({"speedup": round(speedup, 3)}))
"""


DECODE_SCRIPT = r"""
import json, sys, time
import numpy as np, jax
from repro.configs import get_config
from repro.core import Comm, MigratoryStrategy
from repro.engine import DecodeServer, EngineService
from repro.models.transformer import moe_decode_params

out_path = sys.argv[1]
n_seqs, max_new, workers = int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4])
slo_ms, require_p99_ms = float(sys.argv[5]), float(sys.argv[6])

cfg = get_config("serve-moe")
params = moe_decode_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
prompts = [rng.integers(1, cfg.vocab_size, size=int(rng.integers(2, 6))).tolist()
           for _ in range(n_seqs)]

MODES = (("ep_pull", MigratoryStrategy(comm=Comm.MIGRATE), 4),
         ("ep_push", MigratoryStrategy(comm=Comm.REMOTE_WRITE), 4),
         ("tp", None, 1))

def drive(server):
    # staggered joins: half the sequences arrive while others are mid-decode,
    # so the batch composition changes between steps (continuous batching)
    for i, prompt in enumerate(prompts):
        server.add(prompt, max_new_tokens=max_new)
        if i % 2:
            server.step()
    server.run_until_drained()
    return dict(server.results), server.steps

record = {"config": "serve-moe", "n_seqs": n_seqs, "max_new": max_new,
          "workers": workers, "slo_ms": slo_ms,
          "require_p99_ms": require_p99_ms, "modes": {}}
for name, st, nod in MODES:
    svc = EngineService(workers=workers, slo_target_seconds=slo_ms / 1e3)
    svc.start()
    t0 = time.perf_counter()
    try:
        served, steps = drive(DecodeServer(
            cfg, params, capacity=8, max_len=32, nodelets=nod,
            strategy=st, service=svc))
    finally:
        svc.stop()
    wall = time.perf_counter() - t0
    stats = svc.stats().to_dict()
    oracle, _ = drive(DecodeServer(
        cfg, params, capacity=8, max_len=32, nodelets=nod,
        strategy=st, oracle=True))
    assert served == oracle, f"{name}: served tokens diverged from the oracle"
    tokens = sum(len(v) for v in served.values())
    record["modes"][name] = {
        "nodelets": nod, "steps": steps, "tokens": tokens,
        "wall_seconds": wall,
        "tokens_per_second": tokens / wall if wall > 0 else 0.0,
        "oracle_parity": True,
        "queue_wait_p99": stats["queue_wait_p99"],
        "service_p99": stats["service_p99"],
        "total_p99": stats["total_p99"],
        "slo_checked": stats["slo_checked"],
        "slo_violations": stats["slo_violations"],
        "slo_attainment": stats["slo_attainment"],
    }
    if require_p99_ms > 0:
        assert stats["slo_checked"] > 0, f"{name}: SLO gate saw zero requests"
        p99 = stats["total_p99"] * 1e3
        assert p99 <= require_p99_ms, (
            f"{name}: end-to-end p99 {p99:.1f} ms exceeds the "
            f"--require-p99 gate of {require_p99_ms:g} ms")
with open(out_path, "w") as f:
    json.dump(record, f, indent=2, default=str)
print("SERVE-DECODE-OK")
"""


def _run_decode_phase(
    n_seqs: int, max_new: int, workers: int, slo_ms: float, require_p99_ms: float,
) -> dict:
    env = dict(os.environ)
    src = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    DECODE_STATS_PATH.parent.mkdir(parents=True, exist_ok=True)
    proc = subprocess.run(
        [sys.executable, "-c", DECODE_SCRIPT, str(DECODE_STATS_PATH),
         str(n_seqs), str(max_new), str(workers), str(slo_ms),
         str(require_p99_ms)],
        env=env, capture_output=True, text=True, timeout=3600,
    )
    if proc.returncode != 0 or "SERVE-DECODE-OK" not in proc.stdout:
        raise RuntimeError(
            f"serve decode subprocess failed (rc={proc.returncode}):\n"
            f"stdout={proc.stdout}\nstderr={proc.stderr}"
        )
    return json.loads(DECODE_STATS_PATH.read_text())


def _run_pool_phase(
    grid: int, scale: int, tokens: int, reps: int, workers: int,
    min_speedup: float,
) -> dict:
    env = dict(os.environ)
    src = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    POOL_STATS_PATH.parent.mkdir(parents=True, exist_ok=True)
    proc = subprocess.run(
        [sys.executable, "-c", POOL_SCRIPT, str(POOL_STATS_PATH),
         str(grid), str(scale), str(tokens), str(reps),
         str(workers), str(min_speedup)],
        env=env, capture_output=True, text=True, timeout=3600,
    )
    if proc.returncode != 0 or "SERVE-POOL-OK" not in proc.stdout:
        raise RuntimeError(
            f"serve pool subprocess failed (rc={proc.returncode}):\n"
            f"stdout={proc.stdout}\nstderr={proc.stderr}"
        )
    return json.loads(POOL_STATS_PATH.read_text())


def _run_phase(phase: str, grids, scale: int, per: int) -> dict:
    env = dict(os.environ)
    src = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as tmp:
        out_path = tmp.name
    try:
        proc = subprocess.run(
            [sys.executable, "-c", SCRIPT, phase, out_path,
             ",".join(str(g) for g in grids), str(scale), str(per)],
            env=env, capture_output=True, text=True, timeout=1800,
        )
        if proc.returncode != 0 or f"SERVE-{phase.upper()}-OK" not in proc.stdout:
            raise RuntimeError(
                f"serve {phase} subprocess failed (rc={proc.returncode}):\n"
                f"stdout={proc.stdout}\nstderr={proc.stderr}"
            )
        return json.loads(Path(out_path).read_text())
    finally:
        Path(out_path).unlink(missing_ok=True)


def run(
    full: bool = False,
    quick: bool = False,
    workers: "int | None" = None,
    min_pool_speedup: float = 0.0,
    require_p99_ms: float = 0.0,
):
    if quick:
        grids, scale, per = (12, 16), 8, 8
        pool_sizes = (128, 10, 2048, 16)  # spmv grid, bfs scale, moe tokens, reps
        decode_sizes = (4, 4)  # sequences, max_new_tokens
    elif full:
        grids, scale, per = (32, 48, 64), 11, 32
        pool_sizes = (256, 11, 4096, 24)
        decode_sizes = (8, 8)
    else:
        grids, scale, per = (16, 24), 9, 12
        pool_sizes = (128, 10, 2048, 16)
        decode_sizes = (6, 6)
    rows = []
    decode = _run_decode_phase(
        *decode_sizes, workers=2,
        slo_ms=require_p99_ms if require_p99_ms > 0 else 10_000.0,
        require_p99_ms=require_p99_ms,
    )
    for mode, d in decode["modes"].items():
        rows.append(emit(
            "serve", f"decode_{mode}", d["wall_seconds"],
            op="moe_decode", substrate="local",
            nodelets=d["nodelets"], steps=d["steps"], tokens=d["tokens"],
            tokens_per_second=round(d["tokens_per_second"], 1),
            oracle_parity=d["oracle_parity"],
            total_p99=round(d["total_p99"], 6),
            slo_checked=d["slo_checked"],
            slo_violations=d["slo_violations"],
            slo_attainment=d["slo_attainment"],
        ))
    if workers is not None and workers > 1:
        pool = _run_pool_phase(*pool_sizes, workers, min_pool_speedup)
        pooled = pool["stats_workers_pooled"]
        rows.append(emit(
            "serve", "pool_baseline", pool["burst_wall_1"],
            requests=pool["requests_per_burst"],
            req_per_s=round(pool["throughput_1"], 1),
            workers=1,
        ))
        rows.append(emit(
            "serve", "pool_workers", pool["burst_wall_pooled"],
            requests=pool["requests_per_burst"],
            req_per_s=round(pool["throughput_pooled"], 1),
            workers=pool["workers"],
            steals=pooled["steals"],
            worker_requests=pooled["worker_requests"],
            worker_occupancy=[round(o, 3) for o in pooled["worker_occupancy"]],
        ))
        rows.append(emit(
            "serve", "pool_speedup", pool["burst_wall_pooled"],
            pool_speedup=round(pool["pool_speedup"], 3),
            plan_keys=pool["plan_keys"],
            dedup_hits=pool["dedup_hits"],
            dedup_coalesced=pool["dedup_coalesced"],
            bit_identical=pool["bit_identical"],
        ))
    sync = _run_phase("sync", grids, scale, per)
    rows.append(emit(
        "serve", "sync_drain", sync["wall_seconds"],
        requests=sync["requests"],
        req_per_s=round(sync["requests_per_second"], 1),
        compiles=sync["compiles"],
        cache_hits=sync["cache_hits"],
        queue_wait_p95=round(sync["queue_wait_p95"], 6),
        service_p95=round(sync["service_p95"], 6),
    ))
    a = _run_phase("async", grids, scale, per)
    rows.append(emit(
        "serve", "async_worker", a["wall_seconds"],
        requests=a["requests"],
        req_per_s=round(a["requests_per_second"], 1),
        compiles=a["compiles"],
        cache_hits=a["cache_hits"],
        overlap_seconds=a["overlap_seconds"],  # unrounded: run.py gates on > 0
        overlap_ratio=a["overlap_ratio"],
        busy_seconds=round(a["busy_seconds"], 4),
        queue_depth_hwm=a["queue_depth_hwm"],
        rejected=a["rejected"],
        dedup_hits=a["dedup_hits"],
        queue_wait_p50=round(a["queue_wait_p50"], 6),
        queue_wait_p95=round(a["queue_wait_p95"], 6),
        queue_wait_p99=round(a["queue_wait_p99"], 6),
        service_p50=round(a["service_p50"], 6),
        service_p95=round(a["service_p95"], 6),
        service_p99=round(a["service_p99"], 6),
    ))
    speedup = (
        sync["wall_seconds"] / a["wall_seconds"] if a["wall_seconds"] > 0 else 0.0
    )
    rows.append(emit(
        "serve", "async_vs_sync", a["wall_seconds"],
        sync_wall_seconds=round(sync["wall_seconds"], 4),
        speedup=round(speedup, 3),
        overlap_ratio=round(a["overlap_ratio"], 4),
    ))
    if a["overlap_ratio"] <= 0:
        print("# WARN: serve async_worker saw zero compile/execute overlap")
    return rows
