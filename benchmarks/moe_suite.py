"""MoE dispatch through the engine (ISSUE 4): the fourth MigratoryOp's
strategy A/B on the local substrate, the autotuner's ``auto`` pick, and an
async ``EngineService`` serving phase with the value-keyed dedup cache.

Unlike ``moe_dispatch`` (which lowers the full LM MoE sublayer in a
subprocess and reads collective bytes out of the HLO), this suite runs the
*engine-served* ``moe_dispatch`` op in-process at quick-friendly sizes:
every row is a unified RunReport row (modeled traffic = the roofline
collective-bytes cost model the autotuner ranks), plus a ``service`` row
carrying the serving stats (dedup hits, latency percentiles). Writes
``experiments/moe_bench_results.json``.

The **cross-check phase** (ISSUE 8 acceptance) closes the loop between the
two byte counters: for every expert-parallel scenario x {ep_push, ep_pull}
a subprocess with 8 forced host devices runs the *modeled* traffic (the
``TrafficStats.collective_bytes`` the engine report carries — paper-lens
total bytes across all nodelets at kept-slot granularity) and the *lowered*
traffic (``roofline.analyze`` over the compiled mesh kernel's HLO —
per-instruction wire bytes with the standard all_to_all/all_gather
discounts), and asserts their ratio lies inside a generous honest band.
The two counters measure deliberately different things (total modeled
payload vs wire-level estimate), so the band is wide — [1/8, 8]; observed
ratios sit in ~[2.4, 5.4] — but a sign error, a dropped collective, or a
miscounted payload dimension blows straight through it.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from .util import emit, emit_report

XCHECK_BAND = 8.0

XCHECK_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import numpy as np, jax, jax.numpy as jnp
from repro.core import Comm, MigratoryStrategy
from repro.engine import MoEDispatchInputs, Request, get_substrate, run
from repro.launch import roofline

band = float(sys.argv[1])
scenarios = json.loads(sys.argv[2])
rng = np.random.default_rng(0)
sub = get_substrate("mesh")
out = []
for name, t, d, e, p in scenarios:
    inputs = MoEDispatchInputs(
        x=jnp.asarray(rng.standard_normal((t, d)).astype(np.float32)),
        router=jnp.asarray(rng.standard_normal((d, e)).astype(np.float32)),
        nodelets=p)
    for mode, st in (("ep_push", MigratoryStrategy(comm=Comm.REMOTE_WRITE)),
                     ("ep_pull", MigratoryStrategy(comm=Comm.MIGRATE))):
        _, rep = run(Request("moe_dispatch", inputs, st, "local"))
        modeled = rep.traffic.collective_bytes
        kern = sub.kernel("moe_dispatch")
        f = jax.jit(lambda x, r, st=st, p=p: kern(
            x, r, strategy=st, nodelets=p,
            experts_per_token=inputs.experts_per_token,
            capacity_factor=inputs.capacity_factor))
        lowered = roofline.analyze(
            f.lower(inputs.x, inputs.router).compile().as_text()
        ).bytes_collective
        ratio = modeled / max(lowered, 1.0)
        ok = (1.0 / band) <= ratio <= band
        out.append({"scenario": name, "mode": mode,
                    "modeled_bytes": int(modeled),
                    "lowered_wire_bytes": float(lowered),
                    "ratio": round(ratio, 4), "in_band": ok})
        assert ok, ("modeled-vs-lowered collective bytes out of band",
                    name, mode, modeled, lowered, ratio, band)
print("MOE-XCHECK-OK" + json.dumps(out))
"""


def _run_xcheck_phase(scenarios) -> list:
    """Subprocess modeled-vs-lowered cross-check over the expert-parallel
    scenarios (tp scenarios carry zero collective bytes on both sides and
    are skipped). Raises if any (scenario, mode) pair leaves the band."""
    cases = [s for s in scenarios if s[3] % s[4] == 0]  # ep needs E % P == 0
    if not cases:
        return []
    env = dict(os.environ)
    src = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", XCHECK_SCRIPT, str(XCHECK_BAND),
         json.dumps(cases)],
        env=env, capture_output=True, text=True, timeout=1800,
    )
    marker = "MOE-XCHECK-OK"
    if proc.returncode != 0 or marker not in proc.stdout:
        raise RuntimeError(
            f"moe cross-check subprocess failed (rc={proc.returncode}):\n"
            f"stdout={proc.stdout}\nstderr={proc.stderr}"
        )
    line = next(l for l in proc.stdout.splitlines() if l.startswith(marker))
    return json.loads(line[len(marker):])

OUT_PATH = Path(__file__).resolve().parents[1] / "experiments" / "moe_bench_results.json"


def _scenarios(full: bool, quick: bool):
    # (name, tokens, d_model, experts, nodelets)
    if quick:
        return [
            ("t128_e16_p8", 128, 32, 16, 8),
            ("t96_e6_p4_tp", 96, 16, 6, 4),
        ]
    if full:
        return [
            ("t1024_e16_p8", 1024, 128, 16, 8),
            ("t2048_e32_p8", 2048, 128, 32, 8),
            ("t1536_e6_p8_tp", 1536, 96, 6, 8),
        ]
    return [
        ("t256_e16_p8", 256, 64, 16, 8),
        ("t512_e8_p4", 512, 64, 8, 4),
        ("t192_e6_p4_tp", 192, 32, 6, 4),
    ]


def run(full: bool = False, quick: bool = False):
    from repro.engine import (
        EngineService,
        MoEDispatchInputs,
        PlanCache,
        Request,
        candidate_grid,
        choose_strategy,
    )
    from repro.engine import run as engine_run

    rows = []
    rng = np.random.default_rng(0)
    service_cases = []
    scenarios = _scenarios(full, quick)
    for name, t, d, e, p in scenarios:
        inputs = MoEDispatchInputs(
            x=jnp.asarray(rng.standard_normal((t, d)).astype(np.float32)),
            router=jnp.asarray(rng.standard_normal((d, e)).astype(np.float32)),
            nodelets=p,
        )
        for st in candidate_grid("moe_dispatch"):
            _, rep = engine_run("moe_dispatch", inputs, st, "local")
            rows.append(emit_report(
                "moe", f"{name}_{st.comm.value}", rep, scenario=name,
            ))
        auto = choose_strategy("moe_dispatch", inputs)
        _, rep = engine_run("moe_dispatch", inputs, "auto", "local")
        rows.append(emit_report(
            "moe", f"{name}_auto", rep, scenario=name,
            auto_comm=auto.comm.value,
        ))
        service_cases.append((name, inputs))

    # serving phase: repeats of each scenario through the async worker loop
    # with dedup on — repeats after the first completion are answered from
    # the value-keyed response cache
    per = 2 if quick else 4
    svc = EngineService(cache=PlanCache(), dedup=True, batch_window=0.01)
    svc.start()
    try:
        futures = [
            svc.submit(Request("moe_dispatch", inputs, "auto"))
            for _ in range(per)
            for _, inputs in service_cases
        ]
        for f in futures:
            f.result(timeout=600)
    finally:
        svc.stop()
    stats = svc.stats().to_dict()
    rows.append(emit(
        "moe", "service", stats["wall_seconds"],
        op="moe_dispatch", substrate="local",
        requests=stats["requests"],
        dedup_hits=stats["dedup_hits"],
        compiles=stats["compiles"],
        cache_hits=stats["cache_hits"],
        queue_wait_p95=round(stats["queue_wait_p95"], 6),
        service_p50=round(stats["service_p50"], 6),
        service_p95=round(stats["service_p95"], 6),
        service_p99=round(stats["service_p99"], 6),
    ))

    # modeled-vs-lowered collective-bytes cross-check (subprocess, 8 devices)
    for rec in _run_xcheck_phase(scenarios):
        rows.append(emit(
            "moe", f"xcheck_{rec['scenario']}_{rec['mode']}", 0.0,
            op="moe_dispatch", substrate="mesh",
            scenario=rec["scenario"], dispatch_mode=rec["mode"],
            modeled_bytes=rec["modeled_bytes"],
            lowered_wire_bytes=rec["lowered_wire_bytes"],
            modeled_over_lowered=rec["ratio"],
            band=XCHECK_BAND, in_band=rec["in_band"],
        ))
    from .util import machine_header

    OUT_PATH.parent.mkdir(parents=True, exist_ok=True)
    OUT_PATH.write_text(json.dumps(
        [{"bench": "moe", "case": "_machine", **machine_header()}] + rows,
        indent=2, default=str,
    ))
    print(f"# wrote {OUT_PATH} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    run(quick=True)
