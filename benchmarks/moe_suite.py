"""MoE dispatch through the engine (ISSUE 4): the fourth MigratoryOp's
strategy A/B on the local substrate, the autotuner's ``auto`` pick, and an
async ``EngineService`` serving phase with the value-keyed dedup cache.

Unlike ``moe_dispatch`` (which lowers the full LM MoE sublayer in a
subprocess and reads collective bytes out of the HLO), this suite runs the
*engine-served* ``moe_dispatch`` op in-process at quick-friendly sizes:
every row is a unified RunReport row (modeled traffic = the roofline
collective-bytes cost model the autotuner ranks), plus a ``service`` row
carrying the serving stats (dedup hits, latency percentiles). Writes
``experiments/moe_bench_results.json``.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from .util import emit, emit_report

OUT_PATH = Path(__file__).resolve().parents[1] / "experiments" / "moe_bench_results.json"


def _scenarios(full: bool, quick: bool):
    # (name, tokens, d_model, experts, nodelets)
    if quick:
        return [
            ("t128_e16_p8", 128, 32, 16, 8),
            ("t96_e6_p4_tp", 96, 16, 6, 4),
        ]
    if full:
        return [
            ("t1024_e16_p8", 1024, 128, 16, 8),
            ("t2048_e32_p8", 2048, 128, 32, 8),
            ("t1536_e6_p8_tp", 1536, 96, 6, 8),
        ]
    return [
        ("t256_e16_p8", 256, 64, 16, 8),
        ("t512_e8_p4", 512, 64, 8, 4),
        ("t192_e6_p4_tp", 192, 32, 6, 4),
    ]


def run(full: bool = False, quick: bool = False):
    from repro.engine import (
        EngineService,
        MoEDispatchInputs,
        PlanCache,
        candidate_grid,
        choose_strategy,
    )
    from repro.engine import run as engine_run

    rows = []
    rng = np.random.default_rng(0)
    service_cases = []
    for name, t, d, e, p in _scenarios(full, quick):
        inputs = MoEDispatchInputs(
            x=jnp.asarray(rng.standard_normal((t, d)).astype(np.float32)),
            router=jnp.asarray(rng.standard_normal((d, e)).astype(np.float32)),
            nodelets=p,
        )
        for st in candidate_grid("moe_dispatch"):
            _, rep = engine_run("moe_dispatch", inputs, st, "local")
            rows.append(emit_report(
                "moe", f"{name}_{st.comm.value}", rep, scenario=name,
            ))
        auto = choose_strategy("moe_dispatch", inputs)
        _, rep = engine_run("moe_dispatch", inputs, "auto", "local")
        rows.append(emit_report(
            "moe", f"{name}_auto", rep, scenario=name,
            auto_comm=auto.comm.value,
        ))
        service_cases.append((name, inputs))

    # serving phase: repeats of each scenario through the async worker loop
    # with dedup on — repeats after the first completion are answered from
    # the value-keyed response cache
    per = 2 if quick else 4
    svc = EngineService(cache=PlanCache(), dedup=True, batch_window=0.01)
    svc.start()
    try:
        futures = [
            svc.submit("moe_dispatch", inputs, "auto")
            for _ in range(per)
            for _, inputs in service_cases
        ]
        for f in futures:
            f.result(timeout=600)
    finally:
        svc.stop()
    stats = svc.stats().to_dict()
    rows.append(emit(
        "moe", "service", stats["wall_seconds"],
        op="moe_dispatch", substrate="local",
        requests=stats["requests"],
        dedup_hits=stats["dedup_hits"],
        compiles=stats["compiles"],
        cache_hits=stats["cache_hits"],
        queue_wait_p95=round(stats["queue_wait_p95"], 6),
        service_p50=round(stats["service_p50"], 6),
        service_p95=round(stats["service_p95"], 6),
        service_p99=round(stats["service_p99"], 6),
    ))
    from .util import machine_header

    OUT_PATH.parent.mkdir(parents=True, exist_ok=True)
    OUT_PATH.write_text(json.dumps(
        [{"bench": "moe", "case": "_machine", **machine_header()}] + rows,
        indent=2, default=str,
    ))
    print(f"# wrote {OUT_PATH} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    run(quick=True)
