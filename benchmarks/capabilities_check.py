"""Capabilities-table drift check (ISSUE 4 satellite, a CI gate).

    PYTHONPATH=src python -m benchmarks.capabilities_check

Prints the ``engine.capabilities()`` op x substrate table and fails (exit
1) when the table and the raw kernel registry drift apart:

- an op registered with no kernel on any substrate (unservable OpSpec),
- a kernel registered under a substrate kind no registered substrate
  serves (unreachable kernel — usually a typo in ``@kernel(..., kind)``),
- a capabilities cell disagreeing with per-instance kernel resolution
  (``Substrate.kernel`` must succeed exactly where the table says True),
- the live table drifting from the pinned :data:`EXPECTED_CAPABILITIES`
  baseline — gaining or losing an ``(op, substrate)`` pair is a conscious
  edit here, not a silent side effect of a registration change.
"""
from __future__ import annotations

import sys

from repro.engine import (
    OpNotSupportedError,
    capabilities,
    default_registry,
    get_substrate,
    list_substrates,
)


# The pinned support matrix: PR 7 made pallas a real fast path for bfs
# (kernels/bfs); moe_dispatch stays local/mesh-only by design.
EXPECTED_CAPABILITIES = {
    "spmv": {"local": True, "mesh": True, "pallas": True},
    "bfs": {"local": True, "mesh": True, "pallas": True},
    "gsana": {"local": True, "mesh": True, "pallas": True},
    "moe_dispatch": {"local": True, "mesh": True, "pallas": False},
}


def check() -> list[str]:
    reg = default_registry()
    table = capabilities()
    errors: list[str] = []
    subs = list_substrates()
    served_kinds = {get_substrate(s).substrate_kind for s in subs}

    for op_name in reg.ops():
        if op_name not in table:
            errors.append(f"op {op_name!r} missing from capabilities table")
    for op_name, row in table.items():
        if not any(row.values()):
            errors.append(f"op {op_name!r} has no kernel on any substrate")
        for sub_name, claimed in row.items():
            sub = get_substrate(sub_name)
            try:
                sub.kernel(op_name)
                resolved = True
            except OpNotSupportedError:
                resolved = False
            if resolved != claimed:
                errors.append(
                    f"drift: capabilities[{op_name!r}][{sub_name!r}] = {claimed} "
                    f"but kernel resolution says {resolved}"
                )
    for op_name, expected_row in EXPECTED_CAPABILITIES.items():
        live_row = {s: table.get(op_name, {}).get(s) for s in expected_row}
        if live_row != expected_row:
            errors.append(
                f"baseline drift: capabilities[{op_name!r}] = {live_row} "
                f"but the pinned baseline says {expected_row} "
                "(update EXPECTED_CAPABILITIES if this change is intended)"
            )
    for op_name, kind in reg.kernels():
        if kind not in served_kinds:
            errors.append(
                f"kernel ({op_name!r}, {kind!r}) registered under a kind no "
                f"substrate serves (kinds: {sorted(served_kinds)})"
            )
    return errors


def main() -> None:
    table = capabilities()
    subs = list_substrates()
    width = max(len(op) for op in table) + 2
    print("op".ljust(width) + "  ".join(s.ljust(8) for s in subs))
    for op_name in sorted(table):
        cells = ("yes" if table[op_name][s] else "-" for s in subs)
        print(op_name.ljust(width) + "  ".join(c.ljust(8) for c in cells))
    errors = check()
    if errors:
        for err in errors:
            print(f"DRIFT: {err}", file=sys.stderr)
        sys.exit(1)
    print(f"# capabilities OK: {len(table)} ops x {len(subs)} substrates, "
          f"{len(default_registry().kernels())} kernels")


if __name__ == "__main__":
    main()
