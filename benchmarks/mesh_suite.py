"""Mesh-substrate bench smoke: the async EngineService serving shard_map
plans on 8 forced host devices, run in a subprocess so the parent process
keeps its single-device view (DESIGN.md §9 isolation rule).

The child forces ``XLA_FLAGS=--xla_force_host_platform_device_count=8``,
starts the worker loop against the ``mesh`` substrate, submits every case
``repeats`` times, and writes RunReport rows + service/cache stats to
``experiments/mesh_bench_results.json`` (the mesh-8dev CI artifact). Both
the child and the parent assert the mesh-substrate plan cache saw a nonzero
hit-rate — the ROADMAP "cache-aware mesh/pallas benchmarks in CI" gate.

Registered as a slow suite: the default ``--quick`` smoke skips it; the
``mesh-8dev`` CI job runs it explicitly with ``--bench mesh --quick``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

RESULTS_PATH = (
    Path(__file__).resolve().parents[1] / "experiments" / "mesh_bench_results.json"
)

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
).strip()
import json, sys
import numpy as np, jax, jax.numpy as jnp
from repro.core import Comm, MigratoryStrategy, partition_ell
from repro.engine import BFSInputs, EngineService, SpMVInputs
from repro.sparse import edges_to_csr, erdos_renyi_edges, laplacian_2d, partition_graph

out_path, n_grid, scale, repeats = (
    sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4])
)
assert len(jax.devices()) >= 8, f"forced-device count failed: {jax.devices()}"

rng = np.random.default_rng(0)
a = laplacian_2d(n_grid)
x = jnp.asarray(rng.standard_normal(n_grid * n_grid).astype(np.float32))
spmv_inputs = SpMVInputs(partition_ell(a, 8), x)
g = edges_to_csr(erdos_renyi_edges(scale, 6, seed=1), 1 << scale)
bfs_inputs = BFSInputs(partition_graph(g, 8), 0)
cases = [
    ("spmv_replicated", "spmv", spmv_inputs, MigratoryStrategy()),
    ("spmv_striped", "spmv", spmv_inputs, MigratoryStrategy(replicate_x=False)),
    ("bfs_push", "bfs", bfs_inputs, MigratoryStrategy(comm=Comm.REMOTE_WRITE)),
    ("bfs_pull", "bfs", bfs_inputs, MigratoryStrategy(comm=Comm.MIGRATE)),
]

svc = EngineService(substrate="mesh", max_queue_depth=256, batch_window=0.05)
svc.start()
futures = [
    (f"{name}_r{r}", svc.submit(op, inputs, st))
    for r in range(repeats)
    for name, op, inputs, st in cases
]
responses = [(case, fut.result(timeout=900)) for case, fut in futures]
svc.stop()

stats = svc.stats()
cache = svc.cache.stats()
from repro.machine import default_machine, default_machine_path
prof = default_machine()  # 8 forced devices: a parent calibration is stale here
rows = [{
    "bench": "mesh", "case": "_machine",
    "machine_file": str(default_machine_path()),
    "machine_calibrated": prof.calibrated,
    "machine_fingerprint": prof.fingerprint,
}]
rows += [
    {"bench": "mesh", "case": case, **resp.report.to_dict()}
    for case, resp in responses
]
rows.append({"bench": "mesh", "case": "_service", **stats.to_dict()})
rows.append({"bench": "mesh", "case": "_cache", **cache})
with open(out_path, "w") as f:
    json.dump(rows, f, indent=2, default=str)
assert all(resp.report.substrate == "mesh" for _, resp in responses)
assert cache["hits"] > 0, f"mesh plans saw zero cache hits: {cache}"
print("MESH-8DEV-OK", json.dumps({"hits": cache["hits"], "hit_rate": cache["hit_rate"]}))
"""


def run(full: bool = False, quick: bool = False):
    if quick:
        n_grid, scale, repeats = 12, 8, 2
    elif full:
        n_grid, scale, repeats = 32, 11, 4
    else:
        n_grid, scale, repeats = 24, 10, 3
    env = dict(os.environ)
    src = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    RESULTS_PATH.parent.mkdir(parents=True, exist_ok=True)
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT,
         str(RESULTS_PATH), str(n_grid), str(scale), str(repeats)],
        env=env, capture_output=True, text=True, timeout=1800,
    )
    if proc.returncode != 0 or "MESH-8DEV-OK" not in proc.stdout:
        raise RuntimeError(
            f"mesh-8dev subprocess failed (rc={proc.returncode}):\n"
            f"stdout={proc.stdout}\nstderr={proc.stderr}"
        )
    rows = json.loads(RESULTS_PATH.read_text())
    cache_row = next(r for r in rows if r["case"] == "_cache")
    service_row = next(r for r in rows if r["case"] == "_service")
    if not cache_row["hits"] > 0:
        raise RuntimeError(f"mesh plan cache saw zero hits: {cache_row}")
    for row in rows:
        if row["case"].startswith("_"):
            continue
        print(
            f"mesh,{row['case']},{row.get('us_per_call', 0.0):.1f},"
            f"substrate={row.get('substrate')},cache_hit={row.get('cache_hit')}"
        )
    print(
        f"# mesh-8dev: {cache_row['hits']} hits "
        f"(hit rate {cache_row['hit_rate']:.0%}), "
        f"overlap_ratio={service_row['overlap_ratio']:.3f}, "
        f"wrote {RESULTS_PATH} ({len(rows)} rows)"
    )
    return rows
