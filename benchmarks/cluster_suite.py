"""Cluster serving benchmark: 2-worker multi-process throughput + parity.

Launches a localhost cluster (coordinator + N worker subprocesses, each
running its own ``EngineService``), serves a mixed SpMV/BFS/MoE-dispatch
request stream through ``Coordinator.submit`` (the request-level wire
path), and **fails closed** on the two §1h acceptance properties:

- **parity** — every cross-process response must be bit-identical to the
  in-process ``engine.run`` oracle for the same ``Request``; one mismatch
  fails the run (exit 1 via RuntimeError), zero responses also fails;
- **distribution** — with ``n_workers >= 2``, at least two workers must
  have served a nonzero number of requests. A "cluster" where one worker
  served everything (or where the submit path silently fell back
  in-process) is not a cluster result and must not pass green.

The suite also drives a small ``substrate="cluster"`` batch through the
in-process engine so the kernel-level forwarding path (``ClusterSubstrate
-> Coordinator.kernel_call -> worker _KernelCache``) is measured alongside
the request-level path, and writes the per-worker/coordinator stats
artifact ``experiments/cluster_stats.json`` (CI uploads it; it is written
*before* the gates assert so a gate failure still leaves the diagnosis).

Throughput rows report sustained req/s for the cross-process stream next
to the single-process baseline on the identical stream. At smoke sizes
the wire + IPC overhead dominates tiny kernels, so the ratio is reported,
not gated — the gated signal here is correctness of distribution, which
is what the PR-5 pool gates cannot see.

The **data-plane phase** (PR 10) serves the decode-serving traffic shape
— one large shared operand plus a fresh small vector per request — and
compares actual bytes on the wire (protocol v2: out-of-band segments +
content-addressed blobs, submit coalescing) against what the v1 encoding
(8-byte prefix + fully inline base64 JSON) would have spent on the same
stream. ``--require-wire-reduction X`` turns the ratio into a fail-closed
gate: v1/v2 must be >= X and the shared operand must actually have been
served by reference (``blob_hits > 0``), both also recorded in the stats
artifact.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from .util import emit

STATS_PATH = (
    Path(__file__).resolve().parents[1] / "experiments" / "cluster_stats.json"
)


def _workload(n_requests: int, seed: int = 0):
    """Mixed-op request stream: SpMV (two signatures) / BFS / MoE dispatch."""
    import jax.numpy as jnp

    from repro.core import partition_ell
    from repro.engine import (
        BFSInputs,
        MoEDispatchInputs,
        Request,
        SpMVInputs,
    )
    from repro.sparse import (
        edges_to_csr,
        erdos_renyi_edges,
        laplacian_2d,
        partition_graph,
    )

    rng = np.random.default_rng(seed)
    spmv_pool = []
    for n in (12, 16):
        a = partition_ell(laplacian_2d(n), 8)
        x = jnp.asarray(rng.standard_normal(n * n).astype(np.float32))
        spmv_pool.append(SpMVInputs(a, x))
    g = partition_graph(edges_to_csr(erdos_renyi_edges(8, 6, seed=seed), 256), 8)
    bfs_inputs = BFSInputs(g, 0)
    moe_inputs = MoEDispatchInputs(
        x=jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32)),
        router=jnp.asarray(rng.standard_normal((16, 8)).astype(np.float32)),
        nodelets=4,
    )

    requests = []
    for i in range(n_requests):
        if i % 4 == 2:
            requests.append(Request("bfs", bfs_inputs))
        elif i % 4 == 3:
            requests.append(Request("moe_dispatch", moe_inputs))
        else:
            requests.append(Request("spmv", spmv_pool[i % 2]))
    return requests


def _data_plane_workload(n_requests: int, seed: int = 7):
    """Decode-serving traffic shape: one large shared operand (crosses as a
    content-addressed blob) + a fresh small vector per request (crosses as
    a raw frame segment — the per-step delta)."""
    import jax.numpy as jnp

    from repro.core import partition_ell
    from repro.engine import Request, SpMVInputs
    from repro.sparse import laplacian_2d

    rng = np.random.default_rng(seed)
    # cols + vals are ~80 KiB each — above the 64 KiB blob threshold
    a = partition_ell(laplacian_2d(64), 8)
    n = 64 * 64
    return [
        Request(
            "spmv",
            SpMVInputs(
                a, jnp.asarray(rng.standard_normal(n).astype(np.float32))
            ),
        )
        for _ in range(n_requests)
    ]


def _v1_frame_bytes(request) -> int:
    """Bytes the v1 wire (8-byte length prefix + fully inline base64 JSON
    frame) would have spent on one submit of this request."""
    payload = request.to_wire()  # no segments/blob_sink == the v1 encoding
    frame = json.dumps(
        {"kind": "submit", "request": payload, "ticket": 0},
        separators=(",", ":"),
    ).encode("utf-8")
    return 8 + len(frame)


def _bit_identical(a, b) -> bool:
    import jax

    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    if len(la) != len(lb):
        return False
    return all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


def run(
    full: bool = False,
    quick: bool = False,
    n_workers: int = 2,
    require_wire_reduction: "float | None" = None,
) -> list:
    from repro.cluster import launch_cluster
    from repro.engine import EngineService, Request, run as engine_run

    n_requests = 12 if quick else (48 if full else 24)
    requests = _workload(n_requests)
    rows: list = []

    # in-process oracle + single-process baseline on the identical stream
    # (oracles computed first so the cluster phase measures serving alone)
    t0 = time.perf_counter()
    oracles = [engine_run(r, iters=1, warmup=0)[0] for r in requests]
    local_wall = time.perf_counter() - t0
    rows.append(emit(
        "cluster", "local_baseline", local_wall,
        requests=n_requests, req_per_s=n_requests / max(local_wall, 1e-9),
    ))

    t_launch = time.perf_counter()
    with launch_cluster(n_workers) as cluster:
        startup = time.perf_counter() - t_launch
        t0 = time.perf_counter()
        futures = [cluster.submit(r) for r in requests]
        responses = [f.result() for f in futures]
        wall = time.perf_counter() - t0

        mismatches = sum(
            0 if _bit_identical(resp.result, oracle) else 1
            for resp, oracle in zip(responses, oracles)
        )

        # kernel-level path: the PR-5 pool (worker-loop mode) over
        # process-spanning placement slots
        svc = EngineService(substrate="cluster", workers=n_workers).start()
        try:
            t0 = time.perf_counter()
            pool_futures = [
                svc.submit(Request(r.op, r.inputs, r.strategy, "cluster"))
                for r in requests[: max(4, n_requests // 4)]
            ]
            pool_responses = [f.result(timeout=300) for f in pool_futures]
            pool_wall = time.perf_counter() - t0
        finally:
            svc.stop()
        pool_mismatches = sum(
            0 if _bit_identical(resp.result, oracle) else 1
            for resp, oracle in zip(pool_responses, oracles)
        )
        resize = svc.stats().resize_signal()

        # data-plane phase: repeated-large-input stream; the shared operand
        # ships once per worker as a blob, later submits carry only deltas
        dp_n = 8 if quick else (24 if full else 12)
        dp_requests = _data_plane_workload(dp_n)
        dp_oracles = [engine_run(r, iters=1, warmup=0)[0] for r in dp_requests]
        before = cluster.stats()
        t0 = time.perf_counter()
        dp_responses = [
            f.result() for f in [cluster.submit(r) for r in dp_requests]
        ]
        dp_wall = time.perf_counter() - t0
        after = cluster.stats()
        dp_mismatches = sum(
            0 if _bit_identical(resp.result, oracle) else 1
            for resp, oracle in zip(dp_responses, dp_oracles)
        )
        v2_bytes = after["wire_bytes_sent"] - before["wire_bytes_sent"]
        blob_hits = after["blob_hits"] - before["blob_hits"]
        blob_misses = after["blob_misses"] - before["blob_misses"]
        t0 = time.perf_counter()
        v1_bytes = sum(_v1_frame_bytes(r) for r in dp_requests)
        v1_encode_wall = time.perf_counter() - t0
        wire_reduction = v1_bytes / max(v2_bytes, 1)

        stats = cluster.stats()
        worker_stats = {
            w["worker_id"]: cluster.coordinator.worker_stats(w["worker_id"])
            for w in stats["workers"]
            if w["state"] == "healthy"
        }

    served = {w["worker_id"]: int(w["served"]) for w in stats["workers"]}
    workers_used = sum(1 for n in served.values() if n > 0)
    rows.append(emit(
        "cluster", f"submit_{n_workers}w", wall,
        requests=len(responses), req_per_s=len(responses) / max(wall, 1e-9),
        workers=n_workers, workers_used=workers_used,
        mismatches=mismatches, startup_seconds=round(startup, 3),
        vs_local=round(local_wall / max(wall, 1e-9), 3),
    ))
    rows.append(emit(
        "cluster", f"pool_{n_workers}w", pool_wall,
        requests=len(pool_responses),
        req_per_s=len(pool_responses) / max(pool_wall, 1e-9),
        kernel_calls=int(stats["kernel_calls"]),
        mismatches=pool_mismatches, resize_signal=resize,
    ))
    rows.append(emit(
        "cluster", "data_plane", dp_wall,
        requests=dp_n, req_per_s=dp_n / max(dp_wall, 1e-9),
        v1_bytes=v1_bytes, v2_bytes=v2_bytes,
        wire_reduction=round(wire_reduction, 2),
        blob_hits=blob_hits, blob_misses=blob_misses,
        submits_coalesced=int(stats["submits_coalesced"]),
        v1_encode_seconds=round(v1_encode_wall, 4),
        mismatches=dp_mismatches,
    ))

    STATS_PATH.parent.mkdir(parents=True, exist_ok=True)
    STATS_PATH.write_text(json.dumps({
        "n_workers": n_workers,
        "requests": len(responses),
        "wall_seconds": wall,
        "local_wall_seconds": local_wall,
        "per_worker_served": served,
        "mismatches": mismatches,
        "pool_mismatches": pool_mismatches,
        "resize_signal": resize,
        "blob_hits": blob_hits,
        "data_plane": {
            "requests": dp_n,
            "wall_seconds": dp_wall,
            "v1_bytes": v1_bytes,
            "v2_bytes": v2_bytes,
            "wire_reduction": wire_reduction,
            "blob_hits": blob_hits,
            "blob_misses": blob_misses,
            "submit_frames": int(stats["submit_frames"]),
            "submits_coalesced": int(stats["submits_coalesced"]),
            "v1_encode_seconds": v1_encode_wall,
            "mismatches": dp_mismatches,
            "require_wire_reduction": require_wire_reduction,
        },
        "coordinator": stats,
        "worker_service_stats": worker_stats,
    }, indent=2, default=str))
    print(f"# wrote {STATS_PATH}")

    # the fail-closed gates run after the artifact lands on disk, so a red
    # run still uploads the stats that explain it
    if not responses:
        raise RuntimeError("cluster suite served zero requests")
    if mismatches or pool_mismatches or dp_mismatches:
        raise RuntimeError(
            f"cluster parity broken: {mismatches} submit-path, "
            f"{pool_mismatches} pool-path, and {dp_mismatches} data-plane "
            "responses diverged from engine.run"
        )
    if require_wire_reduction:
        if blob_hits <= 0:
            raise RuntimeError(
                "data-plane phase recorded zero blob_hits: the repeated "
                "operand was re-shipped every submit instead of served by "
                "reference"
            )
        if wire_reduction < require_wire_reduction:
            raise RuntimeError(
                f"wire reduction {wire_reduction:.2f}x "
                f"({v1_bytes} -> {v2_bytes} bytes) is below the "
                f"required {require_wire_reduction:g}x"
            )
    if workers_used < min(2, n_workers):
        raise RuntimeError(
            f"requests were not distributed: per-worker served={served} "
            f"(need >= {min(2, n_workers)} workers with nonzero served)"
        )
    if stats["kernel_calls"] <= 0:
        raise RuntimeError(
            "substrate='cluster' pool phase forwarded zero kernel calls "
            "cross-process"
        )
    return rows
