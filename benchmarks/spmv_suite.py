"""SpMV benchmarks: paper Figs. 4-6 + Table 3.

- fig4_grain:       grain-size sweep, striped x (no replication)
- fig5_replication: same sweep with x replicated (S1)
- fig6_scaling:     single-node (8 nodelets) vs multi-node (64) thread sweep
- table3_realworld: degree-signature proxies of the paper's matrices,
                    incl. the Stanford/ins2 hub pathology and the
                    split-long-rows mitigation (paper §5.1 future work)
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import (
    MigratoryStrategy, effective_bandwidth, gather_result, partition_ell, spmv,
    spmv_traffic, stripe_vector,
)
from repro.sparse import TABLE3_SIGNATURES, laplacian_2d, skewed_matrix, split_long_rows

from .util import emit, time_fn

GRID_SMALL = (24, 48, 96)  # n -> n^2-row Laplacians: 576, 2304, 9216 rows
GRAINS = (1, 4, 16, 64, 256)


def fig4_grain(full: bool = False):
    rows = []
    grids = GRID_SMALL + ((160,) if full else ())
    for n in grids:
        a = laplacian_2d(n)
        x = jnp.asarray(np.random.default_rng(0).standard_normal(n * n).astype(np.float32))
        pe = partition_ell(a, 8)
        xs = stripe_vector(x, 8)
        for grain in GRAINS:
            st = MigratoryStrategy(replicate_x=False, grain=grain)
            sec = time_fn(lambda: spmv(pe, xs, st))
            bw = effective_bandwidth(pe, n * n, sec)
            mig = spmv_traffic(pe, st).migrations
            rows.append(emit(
                "fig4_spmv_grain", f"n={n}_grain={grain}", sec,
                bw_mb_s=round(bw / 1e6, 1), migrations=mig,
            ))
    return rows


def fig5_replication(full: bool = False):
    rows = []
    grids = GRID_SMALL + ((160,) if full else ())
    for n in grids:
        a = laplacian_2d(n)
        x = jnp.asarray(np.random.default_rng(0).standard_normal(n * n).astype(np.float32))
        pe = partition_ell(a, 8)
        for grain in GRAINS:
            st = MigratoryStrategy(replicate_x=True, grain=grain)
            sec = time_fn(lambda: spmv(pe, x, st))
            bw = effective_bandwidth(pe, n * n, sec)
            rows.append(emit(
                "fig5_spmv_replication", f"n={n}_grain={grain}", sec,
                bw_mb_s=round(bw / 1e6, 1), migrations=0,
            ))
    return rows


def fig6_scaling(full: bool = False):
    rows = []
    n = 96 if not full else 160
    a = laplacian_2d(n)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(n * n).astype(np.float32))
    for p, label in ((8, "SN_8nodelets"), (64, "MN_64nodelets")):
        pe = partition_ell(a, p)
        for threads in (64, 256, 1024, 2048, 4096):
            grain = max(1, (pe.rows_per_nodelet * p) // threads)
            st = MigratoryStrategy(replicate_x=True, grain=grain)
            sec = time_fn(lambda: spmv(pe, x, st))
            bw = effective_bandwidth(pe, n * n, sec)
            rows.append(emit(
                "fig6_spmv_scaling", f"{label}_threads={threads}", sec,
                bw_mb_s=round(bw / 1e6, 1), grain=grain,
            ))
    return rows


def table3_realworld(full: bool = False):
    rows = []
    sigs = TABLE3_SIGNATURES if full else TABLE3_SIGNATURES[::2] + TABLE3_SIGNATURES[-2:]
    for name, n, avg, mx in sigs:
        n_eff = n if full else max(n // 4, 2000)
        a = skewed_matrix(n_eff, avg, min(mx, n_eff - 1), seed=1)
        lens = np.diff(np.asarray(a.indptr))
        kmax = int(lens.max())
        x = jnp.asarray(np.random.default_rng(0).standard_normal(n_eff).astype(np.float32))
        pe = partition_ell(a, 8, k=kmax)
        st = MigratoryStrategy(replicate_x=True, grain=None)
        sec = time_fn(lambda: spmv(pe, x, st), iters=3)
        bw = effective_bandwidth(pe, n_eff, sec)
        rows.append(emit(
            "table3_spmv_realworld", name, sec,
            bw_mb_s=round(bw / 1e6, 1), avg_deg=round(float(lens.mean()), 2),
            max_deg=kmax,
        ))
        if kmax > 500:  # hub mitigation: split long rows (paper future work)
            s, owner = split_long_rows(a, k=64)
            pe2 = partition_ell(s, 8, k=64)
            sec2 = time_fn(lambda: spmv(pe2, x, st), iters=3)
            bw2 = effective_bandwidth(pe, n_eff, sec2)
            rows.append(emit(
                "table3_spmv_realworld", f"{name}+rowsplit", sec2,
                bw_mb_s=round(bw2 / 1e6, 1), max_deg=64,
            ))
    return rows


def run(full: bool = False):
    return (
        fig4_grain(full) + fig5_replication(full) + fig6_scaling(full)
        + table3_realworld(full)
    )
