"""SpMV benchmarks: paper Figs. 4-6 + Table 3, all through ``engine.run``.

- fig4_grain:       grain-size sweep, striped x (no replication)
- fig5_replication: same sweep with x replicated (S1)
- fig6_scaling:     single-node (8 nodelets) vs multi-node (64) thread sweep
- table3_realworld: degree-signature proxies of the paper's matrices,
                    incl. the Stanford/ins2 hub pathology and the
                    split-long-rows mitigation (paper §5.1 future work)
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import MigratoryStrategy, partition_ell
from repro.engine import SpMVInputs, SpMVOp, run as engine_run
from repro.sparse import TABLE3_SIGNATURES, laplacian_2d, skewed_matrix, split_long_rows

from .util import emit_report

GRID_SMALL = (24, 48, 96)  # n -> n^2-row Laplacians: 576, 2304, 9216 rows
GRAINS = (1, 4, 16, 64, 256)


def _problem(n: int, p: int = 8, k: int | None = None):
    a = laplacian_2d(n)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(n * n).astype(np.float32))
    return SpMVInputs(partition_ell(a, p, k=k), x)


def fig4_grain(full: bool = False, quick: bool = False):
    rows = []
    grids = (GRID_SMALL[0],) if quick else GRID_SMALL + ((160,) if full else ())
    grains = (1, 16) if quick else GRAINS
    for n in grids:
        inputs = _problem(n)
        for grain in grains:
            st = MigratoryStrategy(replicate_x=False, grain=grain)
            _, rep = engine_run(SpMVOp(), inputs, st, "local")
            rows.append(emit_report("fig4_spmv_grain", f"n={n}_grain={grain}", rep))
    return rows


def fig5_replication(full: bool = False, quick: bool = False):
    rows = []
    grids = (GRID_SMALL[0],) if quick else GRID_SMALL + ((160,) if full else ())
    grains = (1, 16) if quick else GRAINS
    for n in grids:
        inputs = _problem(n)
        for grain in grains:
            st = MigratoryStrategy(replicate_x=True, grain=grain)
            _, rep = engine_run(SpMVOp(), inputs, st, "local")
            rows.append(emit_report("fig5_spmv_replication", f"n={n}_grain={grain}", rep))
    return rows


def fig6_scaling(full: bool = False, quick: bool = False):
    rows = []
    n = 24 if quick else (160 if full else 96)
    threads_sweep = (64, 1024) if quick else (64, 256, 1024, 2048, 4096)
    for p, label in ((8, "SN_8nodelets"), (64, "MN_64nodelets")):
        inputs = _problem(n, p)
        for threads in threads_sweep:
            grain = max(1, (inputs.a.rows_per_nodelet * p) // threads)
            st = MigratoryStrategy(replicate_x=True, grain=grain)
            _, rep = engine_run(SpMVOp(), inputs, st, "local")
            rows.append(emit_report(
                "fig6_spmv_scaling", f"{label}_threads={threads}", rep,
            ))
    return rows


def table3_realworld(full: bool = False, quick: bool = False):
    rows = []
    sigs = TABLE3_SIGNATURES if full else TABLE3_SIGNATURES[::2] + TABLE3_SIGNATURES[-2:]
    if quick:
        sigs = sigs[:2]
    for name, n, avg, mx in sigs:
        n_eff = n if full else max(n // 4, 2000)
        a = skewed_matrix(n_eff, avg, min(mx, n_eff - 1), seed=1)
        lens = np.diff(np.asarray(a.indptr))
        kmax = int(lens.max())
        x = jnp.asarray(np.random.default_rng(0).standard_normal(n_eff).astype(np.float32))
        inputs = SpMVInputs(partition_ell(a, 8, k=kmax), x)
        st = MigratoryStrategy(replicate_x=True, grain=None)
        _, rep = engine_run(SpMVOp(), inputs, st, "local")
        rows.append(emit_report(
            "table3_spmv_realworld", name, rep,
            avg_deg=round(float(lens.mean()), 2), max_deg=kmax,
        ))
        if kmax > 500:  # hub mitigation: split long rows (paper future work)
            s, owner = split_long_rows(a, k=64)
            inputs2 = SpMVInputs(partition_ell(s, 8, k=64), x)
            _, rep2 = engine_run(SpMVOp(), inputs2, st, "local")
            rows.append(emit_report(
                "table3_spmv_realworld", f"{name}+rowsplit", rep2, max_deg=64,
            ))
    return rows


def auto_strategy(full: bool = False, quick: bool = False):
    """``strategy="auto"``: the traffic-model autotuner's pick, end to end
    through the engine (the sweep analogue of paper §5.1's conclusion)."""
    rows = []
    grids = (GRID_SMALL[0],) if quick else GRID_SMALL[:2]
    for n in grids:
        inputs = _problem(n)
        _, rep = engine_run(SpMVOp(), inputs, "auto", "local")
        rows.append(emit_report("spmv_auto", f"n={n}", rep))
    return rows


def run(full: bool = False, quick: bool = False):
    return (
        fig4_grain(full, quick) + fig5_replication(full, quick)
        + fig6_scaling(full, quick) + table3_realworld(full, quick)
        + auto_strategy(full, quick)
    )
