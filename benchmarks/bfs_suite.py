"""BFS benchmarks: paper Figs. 7-9, through ``engine.run``.

- fig7_strategies: migrate vs remote-write traffic + measured MTEPS
- fig8_balance:    Erdős–Rényi (balanced) vs RMAT (skewed) degradation
- fig9_compare:    naive pull-per-round vs the push implementation on this
                   host (the STINGER-vs-MEATBEE x86 analogue)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Comm, MigratoryStrategy, teps
from repro.core.bfs import UNVISITED, _adj_global
from repro.engine import BFSInputs, BFSOp, run as engine_run
from repro.sparse import edges_to_csr, erdos_renyi_edges, partition_graph, rmat_edges

from .util import emit, emit_report, time_fn


def _graph(kind: str, scale: int, ef: int = 8, p: int = 8):
    n = 1 << scale
    edges = (
        erdos_renyi_edges(scale, ef, seed=7)
        if kind == "er"
        else rmat_edges(scale, ef, seed=7)
    )
    g = edges_to_csr(edges, n)
    return partition_graph(g, p)


def fig7_strategies(full: bool = False, quick: bool = False):
    rows = []
    scales = (10,) if quick else ((12, 13, 14, 15, 16) if full else (12, 13, 14))
    for scale in scales:
        inputs = BFSInputs(_graph("er", scale), 0)
        for comm in (Comm.MIGRATE, Comm.REMOTE_WRITE):
            _, rep = engine_run(
                BFSOp(), inputs, MigratoryStrategy(comm=comm), "local",
            )
            rows.append(emit_report(
                "fig7_bfs_strategies", f"scale={scale}_{comm.value}", rep,
                traffic_mb=round(rep.traffic.total_bytes / 1e6, 2),
            ))
    return rows


def fig8_balance(full: bool = False, quick: bool = False):
    rows = []
    scale = 10 if quick else (16 if full else 14)
    for kind in ("er", "rmat"):
        pg = _graph(kind, scale)
        deg = np.asarray(pg.deg)
        _, rep = engine_run(
            BFSOp(), BFSInputs(pg, 0), MigratoryStrategy(comm=Comm.REMOTE_WRITE),
            "local",
        )
        rows.append(emit_report(
            "fig8_bfs_balance", f"{kind}_scale={scale}", rep,
            max_deg=int(deg.max()),
            nodelet_edge_imbalance=round(
                float(deg.sum(axis=1).max() / np.maximum(deg.sum(axis=1).mean(), 1)), 2
            ),
        ))
    return rows


def _bfs_pull_naive(pg, root: int):
    """Naive per-round pull implementation (the STINGER-port analogue):
    gathers parent state for every edge before proposing (extra gather +
    filter work vs the push version)."""
    adj = _adj_global(pg)
    n = adj.shape[0]

    @jax.jit
    def run(root):
        parents0 = jnp.full((n,), UNVISITED, jnp.int32).at[root].set(root)
        frontier0 = jnp.zeros((n,), bool).at[root].set(True)

        def cond(s):
            return s[1].any()

        def body(s):
            parents, frontier = s
            # the migrate-style remote read: P[d] for every candidate edge
            pd = parents[jnp.maximum(adj, 0)]
            valid = frontier[:, None] & (adj >= 0) & (pd == UNVISITED)
            src = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], adj.shape)
            dst = jnp.where(valid, adj, 0)
            prop = jnp.where(valid, src, UNVISITED)
            nP = jnp.full((n,), UNVISITED, jnp.int32).at[dst.reshape(-1)].min(
                prop.reshape(-1), mode="drop")
            newly = (parents == UNVISITED) & (nP != UNVISITED)
            return jnp.where(newly, nP, parents), newly

        parents, _ = jax.lax.while_loop(cond, body, (parents0, frontier0))
        return parents

    return run


def fig9_compare(full: bool = False, quick: bool = False):
    rows = []
    scales = (10,) if quick else ((13, 14, 15, 16) if full else (12, 13, 14))
    for scale in scales:
        pg = _graph("er", scale)
        _, rep = engine_run(
            BFSOp(), BFSInputs(pg, 0), MigratoryStrategy(comm=Comm.REMOTE_WRITE),
            "local",
        )
        rows.append(emit_report("fig9_bfs_compare", f"push_scale={scale}", rep))
        naive = _bfs_pull_naive(pg, 0)
        sec_pull = time_fn(lambda: naive(jnp.int32(0)), iters=3)
        rows.append(emit(
            "fig9_bfs_compare", f"naive_pull_scale={scale}", sec_pull,
            op="bfs", substrate="local",
            mteps=round(teps(rep.metrics["edges_traversed"], sec_pull) / 1e6, 2),
        ))
    return rows


def auto_strategy(full: bool = False, quick: bool = False):
    """``strategy="auto"``: the autotuner's S2 pick (remote write, §5.2)."""
    rows = []
    scale = 10 if quick else (14 if full else 12)
    for kind in ("er", "rmat"):
        inputs = BFSInputs(_graph(kind, scale), 0)
        _, rep = engine_run(BFSOp(), inputs, "auto", "local")
        rows.append(emit_report("bfs_auto", f"{kind}_scale={scale}", rep))
    return rows


def run(full: bool = False, quick: bool = False):
    return (
        fig7_strategies(full, quick) + fig8_balance(full, quick)
        + fig9_compare(full, quick) + auto_strategy(full, quick)
    )
