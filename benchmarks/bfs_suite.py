"""BFS benchmarks: paper Figs. 7-9.

- fig7_strategies: migrate vs remote-write traffic + measured MTEPS
- fig8_balance:    Erdős–Rényi (balanced) vs RMAT (skewed) degradation
- fig9_compare:    naive pull-per-round vs the push implementation on this
                   host (the STINGER-vs-MEATBEE x86 analogue)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Comm, MigratoryStrategy, bfs, bfs_traffic, teps
from repro.core.bfs import UNVISITED, _adj_global, _expand_dense
from repro.sparse import edges_to_csr, erdos_renyi_edges, partition_graph, rmat_edges

from .util import emit, time_fn


def _graph(kind: str, scale: int, ef: int = 8, p: int = 8):
    n = 1 << scale
    edges = (
        erdos_renyi_edges(scale, ef, seed=7)
        if kind == "er"
        else rmat_edges(scale, ef, seed=7)
    )
    g = edges_to_csr(edges, n)
    return partition_graph(g, p)


def fig7_strategies(full: bool = False):
    rows = []
    scales = (12, 13, 14) if not full else (12, 13, 14, 15, 16)
    for scale in scales:
        pg = _graph("er", scale)
        sec = time_fn(lambda: bfs(pg, 0), iters=3)
        for comm in (Comm.MIGRATE, Comm.REMOTE_WRITE):
            st = bfs_traffic(pg, 0, MigratoryStrategy(comm=comm))
            mteps = teps(st.edges_traversed, sec) / 1e6
            rows.append(emit(
                "fig7_bfs_strategies", f"scale={scale}_{comm.value}", sec,
                mteps=round(mteps, 2),
                traffic_mb=round(st.traffic.total_bytes / 1e6, 2),
                rounds=st.rounds,
            ))
    return rows


def fig8_balance(full: bool = False):
    rows = []
    scale = 14 if not full else 16
    for kind in ("er", "rmat"):
        pg = _graph(kind, scale)
        deg = np.asarray(pg.deg)
        sec = time_fn(lambda: bfs(pg, 0), iters=3)
        st = bfs_traffic(pg, 0, MigratoryStrategy(comm=Comm.REMOTE_WRITE))
        rows.append(emit(
            "fig8_bfs_balance", f"{kind}_scale={scale}", sec,
            mteps=round(teps(st.edges_traversed, sec) / 1e6, 2),
            max_deg=int(deg.max()),
            nodelet_edge_imbalance=round(
                float(deg.sum(axis=1).max() / np.maximum(deg.sum(axis=1).mean(), 1)), 2
            ),
        ))
    return rows


def _bfs_pull_naive(pg, root: int):
    """Naive per-round pull implementation (the STINGER-port analogue):
    gathers parent state for every edge before proposing (extra gather +
    filter work vs the push version)."""
    adj = _adj_global(pg)
    n = adj.shape[0]

    @jax.jit
    def run(root):
        parents0 = jnp.full((n,), UNVISITED, jnp.int32).at[root].set(root)
        frontier0 = jnp.zeros((n,), bool).at[root].set(True)

        def cond(s):
            return s[1].any()

        def body(s):
            parents, frontier = s
            # the migrate-style remote read: P[d] for every candidate edge
            pd = parents[jnp.maximum(adj, 0)]
            valid = frontier[:, None] & (adj >= 0) & (pd == UNVISITED)
            src = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], adj.shape)
            dst = jnp.where(valid, adj, 0)
            prop = jnp.where(valid, src, UNVISITED)
            nP = jnp.full((n,), UNVISITED, jnp.int32).at[dst.reshape(-1)].min(
                prop.reshape(-1), mode="drop")
            newly = (parents == UNVISITED) & (nP != UNVISITED)
            return jnp.where(newly, nP, parents), newly

        parents, _ = jax.lax.while_loop(cond, body, (parents0, frontier0))
        return parents

    return run


def fig9_compare(full: bool = False):
    rows = []
    scales = (12, 13, 14) if not full else (13, 14, 15, 16)
    for scale in scales:
        pg = _graph("er", scale)
        st = bfs_traffic(pg, 0, MigratoryStrategy(comm=Comm.REMOTE_WRITE))
        sec_push = time_fn(lambda: bfs(pg, 0), iters=3)
        naive = _bfs_pull_naive(pg, 0)
        sec_pull = time_fn(lambda: naive(jnp.int32(0)), iters=3)
        rows.append(emit(
            "fig9_bfs_compare", f"push_scale={scale}", sec_push,
            mteps=round(teps(st.edges_traversed, sec_push) / 1e6, 2),
        ))
        rows.append(emit(
            "fig9_bfs_compare", f"naive_pull_scale={scale}", sec_pull,
            mteps=round(teps(st.edges_traversed, sec_pull) / 1e6, 2),
        ))
    return rows


def run(full: bool = False):
    return fig7_strategies(full) + fig8_balance(full) + fig9_compare(full)
