"""Autotune sweeps: rank the strategy grid per op x scenario with the
paper's traffic model, probe the leading cost-distinct candidates through
the compiled-plan cache, then serve the winner (a cache hit by
construction).

Emits one RunReport row per autotuned scenario and writes the full ranking
tables to ``experiments/autotune_ranking.json`` — the CI artifact that shows
*why* each strategy won (traffic bytes, balance penalty, probe timings).

Probe measurements persist through the default
:class:`~repro.engine.probes.ProbeStore`
(``experiments/autotune_probes.json``, uploaded as a CI artifact next to
the ranking table): a repeat session reuses stored probe seconds instead of
re-executing the probes, and the ranking rows mark reused probes with
``probe_persisted``.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.core import bucketize, generate_alignment_pair, partition_ell, pick_grid
from repro.engine import (
    BFSInputs,
    GSANAInputs,
    SpMVInputs,
    autotune,
    default_probe_store,
    run as engine_run,
)
from repro.sparse import (
    edges_to_csr,
    erdos_renyi_edges,
    laplacian_2d,
    partition_graph,
    rmat_edges,
    skewed_matrix,
)

from .util import emit_report

RANKING_PATH = Path(__file__).resolve().parents[1] / "experiments" / "autotune_ranking.json"


def _spmv(n_grid: int):
    a = laplacian_2d(n_grid)
    n = n_grid * n_grid
    x = jnp.asarray(np.random.default_rng(0).standard_normal(n).astype(np.float32))
    return SpMVInputs(partition_ell(a, 8), x)


def _spmv_skewed(n: int):
    a = skewed_matrix(n, 8, min(96, n - 1), seed=1)
    lens = np.diff(np.asarray(a.indptr))
    x = jnp.asarray(np.random.default_rng(0).standard_normal(n).astype(np.float32))
    return SpMVInputs(partition_ell(a, 8, k=int(lens.max())), x)


def _bfs(kind: str, scale: int):
    n = 1 << scale
    edges = (
        erdos_renyi_edges(scale, 6, seed=7) if kind == "er" else rmat_edges(scale, 6, seed=7)
    )
    return BFSInputs(partition_graph(edges_to_csr(edges, n), 8), 0)


def _gsana(n: int):
    vs1, vs2, pi = generate_alignment_pair(n, seed=3)
    grid = pick_grid(n, 32)
    cap = max(bucketize(vs1, grid).cap, bucketize(vs2, grid).cap)
    return GSANAInputs(
        vs1, vs2, bucketize(vs1, grid, cap=cap), bucketize(vs2, grid, cap=cap),
        ground_truth=pi,
    )


def scenarios(full: bool = False, quick: bool = False):
    """Two scenario shapes per op (the autotune acceptance grid)."""
    if quick:
        sizes = {"spmv": (12, 800), "bfs": (8, 8), "gsana": (192, 256)}
    elif full:
        sizes = {"spmv": (48, 8000), "bfs": (12, 12), "gsana": (1024, 2048)}
    else:
        sizes = {"spmv": (16, 1500), "bfs": (10, 10), "gsana": (256, 384)}
    return [
        ("spmv", f"laplacian_n={sizes['spmv'][0]}", _spmv(sizes["spmv"][0])),
        ("spmv", f"skewed_n={sizes['spmv'][1]}", _spmv_skewed(sizes["spmv"][1])),
        ("bfs", f"er_scale={sizes['bfs'][0]}", _bfs("er", sizes["bfs"][0])),
        ("bfs", f"rmat_scale={sizes['bfs'][1]}", _bfs("rmat", sizes["bfs"][1])),
        ("gsana", f"n={sizes['gsana'][0]}", _gsana(sizes["gsana"][0])),
        ("gsana", f"n={sizes['gsana'][1]}", _gsana(sizes["gsana"][1])),
    ]


def run(full: bool = False, quick: bool = False):
    from .util import machine_header

    rows = []
    ranking_tables = [{"case": "_machine", **machine_header()}]
    ranked_by = set()
    store = default_probe_store()
    for op, case, inputs in scenarios(full, quick):
        tuned = autotune(op, inputs, "local", probe_top_k=2, probe_store=store)
        ranked_by.add(tuned.ranked_by)
        table = [{"case": case, **row} for row in tuned.table()]
        ranking_tables.extend(table)
        # the production run of the winner: a plan-cache hit by construction
        # (when the probe executed this session; a persisted probe skipped it)
        _, rep = engine_run(op, inputs, tuned.best, "local")
        rows.append(emit_report("autotune", f"{op}_{case}", rep, n_candidates=len(table)))
    RANKING_PATH.parent.mkdir(parents=True, exist_ok=True)
    RANKING_PATH.write_text(json.dumps(ranking_tables, indent=2, default=str))
    print(f"# wrote {RANKING_PATH} ({len(ranking_tables)} ranking rows, "
          f"ranked by {'/'.join(sorted(ranked_by))})")
    print(f"# autotune probes: {store.reused} reused from store, "
          f"{store.recorded} newly measured -> {store.path}")
    return rows
