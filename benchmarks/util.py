"""Benchmark harness utilities: warmed, blocked wall-clock timing + the
unified RunReport row schema every suite emits."""
from __future__ import annotations

import time

import jax


def machine_header() -> dict:
    """The calibration provenance every suite's JSON output carries
    (DESIGN.md §1f): which machine file was active, whether it was
    calibrated, and for which topology. Uncalibrated runs say so instead of
    omitting the key — absence of calibration is itself a measurement
    condition worth recording."""
    from repro.machine import default_machine, default_machine_path

    profile = default_machine()
    return {
        "machine_file": str(default_machine_path()),
        "machine_calibrated": profile.calibrated,
        "machine_fingerprint": profile.fingerprint,
        "machine_quick": profile.quick,
    }


def time_fn(fn, *args, iters: int = 5, warmup: int = 2):
    """Median wall seconds per call of fn(*args) (jit-warmed, blocked)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(bench: str, case: str, seconds: float, **derived) -> dict:
    """Free-form row (kernel micro-benches and model-only sweeps). Carries
    the same core keys as the RunReport schema so JSON rows stay comparable."""
    row = {
        "bench": bench, "case": case, "seconds": seconds,
        "us_per_call": seconds * 1e6, **derived,
    }
    extras = ",".join(f"{k}={v}" for k, v in derived.items())
    print(f"{bench},{case},{row['us_per_call']:.1f},{extras}")
    return row


def emit_report(bench: str, case: str, report, **derived) -> dict:
    """Unified row from an ``engine.RunReport``: op, strategy_*, substrate,
    seconds, traffic counts, effective bandwidth, op metrics."""
    row = {"bench": bench, "case": case, **report.to_dict(), **derived}
    keys = ("op", "substrate", "migrations", "remote_writes", "effective_gbps")
    extras = ",".join(f"{k}={row[k]}" for k in keys if k in row)
    if derived:
        extras += "," + ",".join(f"{k}={v}" for k, v in derived.items())
    print(f"{bench},{case},{row['us_per_call']:.1f},{extras}")
    return row
