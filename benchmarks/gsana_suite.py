"""GSANA benchmarks: paper Figs. 10-12.

- fig10_threads: bandwidth (paper's RW-model formula) vs thread count for
  BLK/HCB x ALL (+ PAIR at max threads, as in the paper)
- fig11_layouts: layout/scheme grid across graph sizes (Table 4 subset)
- fig12_scaling: strong scaling, single-node vs multi-node with the
  inter-node migration penalty
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    Scheme, bucketize, compute_similarity, generate_alignment_pair,
    gsana_effective_bw, layout_blk, layout_hcb, pick_grid, plan_stats,
    recall_at_k,
)

from .util import emit, time_fn


def _problem(n: int, seed: int = 3):
    vs1, vs2, pi = generate_alignment_pair(n, seed=seed)
    grid = pick_grid(n, 32)
    cap = max(bucketize(vs1, grid).cap, bucketize(vs2, grid).cap)
    return vs1, vs2, bucketize(vs1, grid, cap=cap), bucketize(vs2, grid, cap=cap), pi


def fig10_threads(full: bool = False):
    rows = []
    n = 1024 if not full else 2048
    vs1, vs2, b1, b2, pi = _problem(n)
    sec_all = time_fn(lambda: compute_similarity(vs1, vs2, b1, b2, scheme=Scheme.ALL), iters=3)
    sec_pair = time_fn(lambda: compute_similarity(vs1, vs2, b1, b2, scheme=Scheme.PAIR), iters=3)
    p = 8
    for name, placement in (
        ("BLK", layout_blk(b1, b2, vs1.n, vs2.n, p)),
        ("HCB", layout_hcb(b1, b2, p)),
    ):
        for threads in (1, 2, 8, 32, 128, 256):
            st = plan_stats(vs1, vs2, b1, b2, placement, Scheme.ALL, p,
                            threads_per_nodelet=max(1, threads // p))
            model_time = sec_all * st.total_comparisons / max(st.speedup_model, 1e-9) / st.total_comparisons
            bw = gsana_effective_bw(vs1, vs2, b1, b2, sec_all / max(st.speedup_model, 1e-9))
            rows.append(emit(
                "fig10_gsana_threads", f"{name}-ALL_t={threads}", sec_all,
                model_speedup=round(st.speedup_model, 1),
                bw_model_mb_s=round(bw / 1e6, 1),
                migrations=st.traffic.migrations,
            ))
        st = plan_stats(vs1, vs2, b1, b2, placement, Scheme.PAIR, p, threads_per_nodelet=32)
        bw = gsana_effective_bw(vs1, vs2, b1, b2, sec_pair / max(st.speedup_model, 1e-9))
        rows.append(emit(
            "fig10_gsana_threads", f"{name}-PAIR_t=256", sec_pair,
            model_speedup=round(st.speedup_model, 1),
            bw_model_mb_s=round(bw / 1e6, 1),
            migrations=st.traffic.migrations,
        ))
    return rows


def fig11_layouts(full: bool = False):
    rows = []
    sizes = (512, 1024, 2048) if not full else (512, 1024, 2048, 4096, 8192)
    p = 8
    for n in sizes:
        vs1, vs2, b1, b2, pi = _problem(n)
        sec = time_fn(lambda: compute_similarity(vs1, vs2, b1, b2, scheme=Scheme.PAIR), iters=3)
        cand, _ = compute_similarity(vs1, vs2, b1, b2, k=4)
        rec = recall_at_k(cand, pi)
        for lname, pl in (
            ("BLK", layout_blk(b1, b2, vs1.n, vs2.n, p)),
            ("HCB", layout_hcb(b1, b2, p)),
        ):
            for scheme in (Scheme.ALL, Scheme.PAIR):
                st = plan_stats(vs1, vs2, b1, b2, pl, scheme, p, threads_per_nodelet=32)
                rows.append(emit(
                    "fig11_gsana_layouts", f"{lname}-{scheme.value.upper()}_n={n}", sec,
                    model_makespan=round(st.makespan, 0),
                    migrations=st.traffic.migrations,
                    recall_at4=round(rec, 3),
                ))
    return rows


def fig12_scaling(full: bool = False):
    rows = []
    n = 2048
    vs1, vs2, b1, b2, _ = _problem(n)
    for setup, p, penalty in (("SN", 8, 0.3), ("MN", 64, 0.9)):
        for lname, pl in (
            ("BLK", layout_blk(b1, b2, vs1.n, vs2.n, p)),
            ("HCB", layout_hcb(b1, b2, p)),
        ):
            for threads in (1, 4, 16, 64, 128):
                st = plan_stats(
                    vs1, vs2, b1, b2, pl, Scheme.ALL, p,
                    threads_per_nodelet=max(1, threads // p),
                    migration_penalty=penalty,
                )
                rows.append(emit(
                    "fig12_gsana_scaling", f"{setup}-{lname}_t={threads}", 0.0,
                    model_speedup=round(st.speedup_model, 2),
                    model_makespan=round(st.makespan, 0),
                ))
    return rows


def run(full: bool = False):
    return fig10_threads(full) + fig11_layouts(full) + fig12_scaling(full)
