"""GSANA benchmarks: paper Figs. 10-12, through ``engine.run``.

Measured executions go through the engine (one RunReport per layout x
scheme); the pure placement-model thread sweeps (no execution, paper's
modeled speedup curves) call ``plan_stats`` directly.
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    Layout, MigratoryStrategy, Scheme, bucketize, generate_alignment_pair,
    layout_blk, layout_hcb, pick_grid, plan_stats,
)
from repro.engine import GSANAInputs, GSANAOp, run as engine_run

from .util import emit, emit_report


def _problem(n: int, seed: int = 3, **kw) -> GSANAInputs:
    vs1, vs2, pi = generate_alignment_pair(n, seed=seed)
    grid = pick_grid(n, 32)
    cap = max(bucketize(vs1, grid).cap, bucketize(vs2, grid).cap)
    return GSANAInputs(
        vs1, vs2, bucketize(vs1, grid, cap=cap), bucketize(vs2, grid, cap=cap),
        ground_truth=pi, **kw,
    )


def fig10_threads(full: bool = False, quick: bool = False):
    rows = []
    n = 512 if quick else (2048 if full else 1024)
    inputs = _problem(n)
    for layout in (Layout.BLK, Layout.HCB):
        for scheme in (Scheme.ALL, Scheme.PAIR):
            st = MigratoryStrategy(layout=layout, scheme=scheme)
            _, rep = engine_run(GSANAOp(), inputs, st, "local")
            rows.append(emit_report(
                "fig10_gsana_threads",
                f"{layout.value.upper()}-{scheme.value.upper()}_t=256", rep,
            ))
        # modeled thread-count sweep (no execution): paper's speedup curves
        placement = (
            layout_hcb(inputs.b1, inputs.b2, 8)
            if layout == Layout.HCB
            else layout_blk(inputs.b1, inputs.b2, inputs.vs1.n, inputs.vs2.n, 8)
        )
        threads_sweep = (8, 256) if quick else (1, 2, 8, 32, 128, 256)
        for threads in threads_sweep:
            ps = plan_stats(
                inputs.vs1, inputs.vs2, inputs.b1, inputs.b2, placement,
                Scheme.ALL, 8, threads_per_nodelet=max(1, threads // 8),
            )
            rows.append(emit(
                "fig10_gsana_threads_model",
                f"{layout.value.upper()}-ALL_t={threads}", 0.0,
                op="gsana", substrate="model",
                model_speedup=round(ps.speedup_model, 1),
                migrations=ps.traffic.migrations,
            ))
    return rows


def fig11_layouts(full: bool = False, quick: bool = False):
    rows = []
    sizes = (512,) if quick else ((512, 1024, 2048, 4096, 8192) if full else (512, 1024, 2048))
    for n in sizes:
        inputs = _problem(n)
        for layout in (Layout.BLK, Layout.HCB):
            for scheme in (Scheme.ALL, Scheme.PAIR):
                st = MigratoryStrategy(layout=layout, scheme=scheme)
                _, rep = engine_run(GSANAOp(), inputs, st, "local")
                rows.append(emit_report(
                    "fig11_gsana_layouts",
                    f"{layout.value.upper()}-{scheme.value.upper()}_n={n}", rep,
                ))
    return rows


def fig12_scaling(full: bool = False, quick: bool = False):
    rows = []
    n = 512 if quick else 2048
    inputs = _problem(n)
    threads_sweep = (4, 64) if quick else (1, 4, 16, 64, 128)
    for setup, p, penalty in (("SN", 8, 0.3), ("MN", 64, 0.9)):
        for lname, pl in (
            ("BLK", layout_blk(inputs.b1, inputs.b2, inputs.vs1.n, inputs.vs2.n, p)),
            ("HCB", layout_hcb(inputs.b1, inputs.b2, p)),
        ):
            for threads in threads_sweep:
                ps = plan_stats(
                    inputs.vs1, inputs.vs2, inputs.b1, inputs.b2, pl, Scheme.ALL, p,
                    threads_per_nodelet=max(1, threads // p),
                    migration_penalty=penalty,
                )
                rows.append(emit(
                    "fig12_gsana_scaling", f"{setup}-{lname}_t={threads}", 0.0,
                    op="gsana", substrate="model",
                    model_speedup=round(ps.speedup_model, 2),
                    model_makespan=round(ps.makespan, 0),
                ))
    return rows


def auto_strategy(full: bool = False, quick: bool = False):
    """``strategy="auto"``: the autotuner's S3 pick (HCB placement, §5.3)."""
    rows = []
    for n in ((512,) if quick else (512, 1024)):
        inputs = _problem(n)
        _, rep = engine_run(GSANAOp(), inputs, "auto", "local")
        rows.append(emit_report("gsana_auto", f"n={n}", rep))
    return rows


def run(full: bool = False, quick: bool = False):
    return (
        fig10_threads(full, quick) + fig11_layouts(full, quick)
        + fig12_scaling(full, quick) + auto_strategy(full, quick)
    )
